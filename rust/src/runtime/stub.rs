//! Deterministic in-process stub executor — the default runtime backend.
//!
//! The real runtime bridge replays AOT-lowered HLO artifacts through the
//! PJRT C API (enable the `pjrt` cargo feature). This module is what runs
//! when that toolchain is absent: a host-side reimplementation of every
//! kernel in the L2 variant registry (`python/compile/model.py`
//! `VARIANTS`), dispatched by artifact name. Each kernel computes exactly
//! what its Pallas counterpart computes — the same math as the oracles in
//! [`crate::coordinator::verify`] — so the functional-replay path
//! ([`crate::coordinator::exec`]), the CLI `run-mm`/`selftest` commands
//! and the e2e examples work bit-for-bit deterministically on any machine
//! with no JAX/XLA installation.
//!
//! Kernels are shape-generic: sizes are read from the input tensors, so a
//! stub "executable" serves any artifact whose name carries the right
//! family prefix (`mm_f32_*`, `fir_cf32_*`, ...).

use super::artifact::ArtifactSpec;
use super::executor::{Tensor, TensorData};
use anyhow::{bail, Result};

/// Kernel families the stub implements (mirror of the python `VARIANTS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    /// `C' = C + A·B` over f32 (accumulate form for host k-chaining).
    MmF32,
    /// Integer MM (wrapping arithmetic, as numpy int32 wraps).
    MmI32,
    /// `acc' = acc + conv2d_valid(x, k)` over f32 (halo-extended input).
    Conv2dF32,
    /// Integer conv (wrapping).
    Conv2dI32,
    /// `y[i] = Σ_t h[t]·x[i+t]` over f32.
    FirF32,
    /// Complex FIR on separate re/im planes.
    FirCf32,
    /// Radix-2 DIT butterfly stages over bit-reversed-order rows.
    Fft1dF32,
    /// `acc' = acc + depthwise_conv(x, k)`: one filter per channel group.
    DwConv2dF32,
    /// Forward-substitution triangular solve `x = L⁻¹ b`.
    TrsvF32,
    /// 5-point Jacobi sweeps (stage count baked into the artifact name).
    Stencil2dF32,
    /// CA-MM replication-axis merge: replica partials summed in slab order.
    CaMmReduceF32,
    /// Gauss–Seidel sweeps, rows bottom-up with a fresh south read
    /// (sweep count baked into the artifact name).
    Seidel2dF32,
}

/// A "compiled" stub kernel: the artifact's signature plus its dispatch.
#[derive(Debug, Clone)]
pub struct StubExecutable {
    spec: ArtifactSpec,
    kind: Kind,
}

fn f32_of<'a>(t: &'a Tensor, name: &str, what: &str) -> Result<&'a [f32]> {
    match &t.data {
        TensorData::F32(v) => Ok(v),
        _ => bail!("{name}: {what} must be f32"),
    }
}

fn i32_of<'a>(t: &'a Tensor, name: &str, what: &str) -> Result<&'a [i32]> {
    match &t.data {
        TensorData::I32(v) => Ok(v),
        _ => bail!("{name}: {what} must be i32"),
    }
}

impl StubExecutable {
    /// "Compile" an artifact: resolve its name to a builtin kernel.
    pub fn compile(spec: &ArtifactSpec) -> Result<Self> {
        let kind = if spec.name.starts_with("mm_f32") {
            Kind::MmF32
        } else if spec.name.starts_with("mm_i32") {
            Kind::MmI32
        } else if spec.name.starts_with("conv2d_f32") {
            Kind::Conv2dF32
        } else if spec.name.starts_with("conv2d_i32") {
            Kind::Conv2dI32
        } else if spec.name.starts_with("fir_f32") {
            Kind::FirF32
        } else if spec.name.starts_with("fir_cf32") {
            Kind::FirCf32
        } else if spec.name.starts_with("fft1d_f32") {
            Kind::Fft1dF32
        } else if spec.name.starts_with("dwconv2d_f32") {
            Kind::DwConv2dF32
        } else if spec.name.starts_with("trsv_f32") {
            Kind::TrsvF32
        } else if spec.name.starts_with("stencil2d_f32") {
            Kind::Stencil2dF32
        } else if spec.name.starts_with("ca_mm_f32") {
            Kind::CaMmReduceF32
        } else if spec.name.starts_with("seidel2d_f32") {
            Kind::Seidel2dF32
        } else {
            bail!(
                "stub executor has no builtin kernel for artifact {:?}; \
                 build with `--features pjrt` to execute arbitrary HLO",
                spec.name
            )
        };
        Ok(Self {
            spec: spec.clone(),
            kind,
        })
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Execute on host tensors. Inputs are assumed already validated
    /// against the artifact signature (the runtime's `run` does that).
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.execute_ref(&refs)
    }

    /// Borrowed-input variant of [`StubExecutable::execute`]: the blocked
    /// replay driver passes tile views borrowed from packed panels, and an
    /// owned-slice signature would force a clone per round.
    pub fn execute_ref(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let name = &self.spec.name;
        match self.kind {
            Kind::MmF32 => {
                let (n, k) = (inputs[0].shape[0], inputs[0].shape[1]);
                let m = inputs[1].shape[1];
                let a = f32_of(&inputs[0], name, "A")?;
                let b = f32_of(&inputs[1], name, "B")?;
                let c = f32_of(&inputs[2], name, "C")?;
                let mut out = c.to_vec();
                // No zero-skip here: 0·Inf must stay NaN so the stub
                // agrees with the XLA artifact on non-finite inputs.
                for i in 0..n {
                    for kk in 0..k {
                        let av = a[i * k + kk];
                        for j in 0..m {
                            out[i * m + j] += av * b[kk * m + j];
                        }
                    }
                }
                Ok(vec![Tensor::f32(vec![n, m], out)])
            }
            Kind::MmI32 => {
                let (n, k) = (inputs[0].shape[0], inputs[0].shape[1]);
                let m = inputs[1].shape[1];
                let a = i32_of(&inputs[0], name, "A")?;
                let b = i32_of(&inputs[1], name, "B")?;
                let c = i32_of(&inputs[2], name, "C")?;
                let mut out = c.to_vec();
                for i in 0..n {
                    for kk in 0..k {
                        let av = a[i * k + kk];
                        if av == 0 {
                            continue;
                        }
                        for j in 0..m {
                            out[i * m + j] =
                                out[i * m + j].wrapping_add(av.wrapping_mul(b[kk * m + j]));
                        }
                    }
                }
                Ok(vec![Tensor::i32(vec![n, m], out)])
            }
            Kind::Conv2dF32 => {
                let (p, q) = (inputs[1].shape[0], inputs[1].shape[1]);
                let (h, w) = (inputs[2].shape[0], inputs[2].shape[1]);
                let xw = w + q - 1;
                let x = f32_of(&inputs[0], name, "X")?;
                let k = f32_of(&inputs[1], name, "K")?;
                let acc = f32_of(&inputs[2], name, "acc")?;
                let mut out = acc.to_vec();
                for i in 0..h {
                    for j in 0..w {
                        let mut s = 0f32;
                        for a in 0..p {
                            for b in 0..q {
                                s += x[(i + a) * xw + (j + b)] * k[a * q + b];
                            }
                        }
                        out[i * w + j] += s;
                    }
                }
                Ok(vec![Tensor::f32(vec![h, w], out)])
            }
            Kind::Conv2dI32 => {
                let (p, q) = (inputs[1].shape[0], inputs[1].shape[1]);
                let (h, w) = (inputs[2].shape[0], inputs[2].shape[1]);
                let xw = w + q - 1;
                let x = i32_of(&inputs[0], name, "X")?;
                let k = i32_of(&inputs[1], name, "K")?;
                let acc = i32_of(&inputs[2], name, "acc")?;
                let mut out = acc.to_vec();
                for i in 0..h {
                    for j in 0..w {
                        let mut s = 0i32;
                        for a in 0..p {
                            for b in 0..q {
                                s = s.wrapping_add(
                                    x[(i + a) * xw + (j + b)].wrapping_mul(k[a * q + b]),
                                );
                            }
                        }
                        out[i * w + j] = out[i * w + j].wrapping_add(s);
                    }
                }
                Ok(vec![Tensor::i32(vec![h, w], out)])
            }
            Kind::FirF32 => {
                let taps = inputs[1].shape[0];
                let n = inputs[0].shape[0] + 1 - taps;
                let x = f32_of(&inputs[0], name, "x")?;
                let h = f32_of(&inputs[1], name, "h")?;
                let y = fir_real(x, h, n);
                Ok(vec![Tensor::f32(vec![n], y)])
            }
            Kind::FirCf32 => {
                let taps = inputs[2].shape[0];
                let n = inputs[0].shape[0] + 1 - taps;
                let xr = f32_of(&inputs[0], name, "x_re")?;
                let xi = f32_of(&inputs[1], name, "x_im")?;
                let hr = f32_of(&inputs[2], name, "h_re")?;
                let hi = f32_of(&inputs[3], name, "h_im")?;
                // (xr + i·xi) ⊛ (hr + i·hi) = (rr − ii) + i·(ri + ir)
                let rr = fir_real(xr, hr, n);
                let ii = fir_real(xi, hi, n);
                let ri = fir_real(xr, hi, n);
                let ir = fir_real(xi, hr, n);
                let yre: Vec<f32> = rr.iter().zip(&ii).map(|(a, b)| a - b).collect();
                let yim: Vec<f32> = ri.iter().zip(&ir).map(|(a, b)| a + b).collect();
                Ok(vec![Tensor::f32(vec![n], yre), Tensor::f32(vec![n], yim)])
            }
            Kind::Fft1dF32 => {
                let (rows, n) = (inputs[0].shape[0], inputs[0].shape[1]);
                if !n.is_power_of_two() {
                    bail!("{name}: FFT length {n} is not a power of two");
                }
                let re_in = f32_of(&inputs[0], name, "re")?;
                let im_in = f32_of(&inputs[1], name, "im")?;
                let mut re = re_in.to_vec();
                let mut im = im_in.to_vec();
                for r in 0..rows {
                    fft_stages_row(&mut re[r * n..(r + 1) * n], &mut im[r * n..(r + 1) * n]);
                }
                Ok(vec![
                    Tensor::f32(vec![rows, n], re),
                    Tensor::f32(vec![rows, n], im),
                ])
            }
            Kind::DwConv2dF32 => {
                let (c, p, q) = (inputs[1].shape[0], inputs[1].shape[1], inputs[1].shape[2]);
                let (h, w) = (inputs[2].shape[1], inputs[2].shape[2]);
                let (xh, xw) = (h + p - 1, w + q - 1);
                let x = f32_of(&inputs[0], name, "X")?;
                let k = f32_of(&inputs[1], name, "K")?;
                let acc = f32_of(&inputs[2], name, "acc")?;
                let mut out = acc.to_vec();
                for g in 0..c {
                    let xg = &x[g * xh * xw..(g + 1) * xh * xw];
                    let kg = &k[g * p * q..(g + 1) * p * q];
                    for i in 0..h {
                        for j in 0..w {
                            let mut s = 0f32;
                            for a in 0..p {
                                for b in 0..q {
                                    s += xg[(i + a) * xw + (j + b)] * kg[a * q + b];
                                }
                            }
                            out[g * h * w + i * w + j] += s;
                        }
                    }
                }
                Ok(vec![Tensor::f32(vec![c, h, w], out)])
            }
            Kind::TrsvF32 => {
                let n = inputs[1].shape[0];
                let l = f32_of(&inputs[0], name, "L")?;
                let b = f32_of(&inputs[1], name, "b")?;
                // one maths definition in rust: the stub runs the verify
                // oracle itself (the artifact it stands in for computes a
                // plain forward substitution, nothing to specialise)
                let x = crate::coordinator::verify::trsv_ref(l, b, n);
                Ok(vec![Tensor::f32(vec![n], x)])
            }
            Kind::Stencil2dF32 => {
                let (n, m) = (inputs[0].shape[0], inputs[0].shape[1]);
                let a = f32_of(&inputs[0], name, "A")?;
                let coef = f32_of(&inputs[1], name, "coef")?;
                if coef.len() != 5 {
                    bail!("{name}: stencil takes 5 coefficients, got {}", coef.len());
                }
                let stages = stencil_stages(name);
                let cur =
                    crate::coordinator::verify::stencil2d_chain_ref(a, n, m, stages, coef);
                Ok(vec![Tensor::f32(vec![n, m], cur)])
            }
            Kind::CaMmReduceF32 => {
                let (rep, n, m) = (inputs[0].shape[0], inputs[0].shape[1], inputs[0].shape[2]);
                let p = f32_of(&inputs[0], name, "partials")?;
                // ascending slab order — the same reduction schedule as
                // verify::ca_mm_ref, so the replay driver bit-matches it
                let mut out = p[..n * m].to_vec();
                for s in 1..rep {
                    for (o, v) in out.iter_mut().zip(&p[s * n * m..(s + 1) * n * m]) {
                        *o += v;
                    }
                }
                Ok(vec![Tensor::f32(vec![n, m], out)])
            }
            Kind::Seidel2dF32 => {
                let (n, m) = (inputs[0].shape[0], inputs[0].shape[1]);
                let a = f32_of(&inputs[0], name, "A")?;
                let coef = f32_of(&inputs[1], name, "coef")?;
                if coef.len() != 5 {
                    bail!("{name}: seidel takes 5 coefficients, got {}", coef.len());
                }
                let stages = stencil_stages(name);
                let cur = crate::coordinator::verify::seidel2d_ref(a, n, m, stages, coef);
                Ok(vec![Tensor::f32(vec![n, m], cur)])
            }
        }
    }
}

/// Sweep count baked into a stencil artifact's name
/// (`stencil2d_f32_<stages>x<n>`); defaults to 2 if unparseable.
fn stencil_stages(name: &str) -> usize {
    name.rsplit('_')
        .next()
        .and_then(|s| s.split('x').next())
        .and_then(|s| s.parse().ok())
        .unwrap_or(2)
}

/// y[i] = Σ_t h[t] · x[i + t] (the artifact's correlation convention).
fn fir_real(x: &[f32], h: &[f32], n: usize) -> Vec<f32> {
    let taps = h.len();
    (0..n)
        .map(|i| (0..taps).map(|t| h[t] * x[i + t]).sum())
        .collect()
}

/// All radix-2 DIT butterfly stages over one row that is already in
/// bit-reversed order (the artifact contract: the host permutes, the
/// kernel runs the stages — see `python/compile/kernels/fft.py`).
fn fft_stages_row(re: &mut [f32], im: &mut [f32]) {
    let n = re.len();
    let mut m = 1;
    while m < n {
        let theta = -std::f64::consts::PI / m as f64;
        for g in (0..n).step_by(2 * m) {
            for j in 0..m {
                let ang = theta * j as f64;
                let (twr, twi) = (ang.cos() as f32, ang.sin() as f32);
                let (br, bi) = (re[g + m + j], im[g + m + j]);
                let (tr, ti) = (br * twr - bi * twi, br * twi + bi * twr);
                let (ar, ai) = (re[g + j], im[g + j]);
                re[g + j] = ar + tr;
                im[g + j] = ai + ti;
                re[g + m + j] = ar - tr;
                im[g + m + j] = ai - ti;
            }
        }
        m *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify;
    use crate::runtime::artifact::Manifest;
    use crate::util::rng::XorShift64;

    fn exe(name: &str) -> StubExecutable {
        let m = Manifest::builtin();
        StubExecutable::compile(m.get(name).unwrap()).unwrap()
    }

    #[test]
    fn mm_matches_oracle() {
        let n = 128;
        let mut rng = XorShift64::new(11);
        let mut a = vec![0f32; n * n];
        let mut b = vec![0f32; n * n];
        let mut c = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        rng.fill_f32(&mut c);
        let out = exe("mm_f32_128")
            .execute(&[
                Tensor::f32(vec![n, n], a.clone()),
                Tensor::f32(vec![n, n], b.clone()),
                Tensor::f32(vec![n, n], c.clone()),
            ])
            .unwrap();
        let want = verify::mm_ref(&a, &b, &c, n, n, n);
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-3);
    }

    #[test]
    fn mm_i32_all_ones() {
        let n = 128;
        let a = Tensor::i32(vec![n, n], vec![1; n * n]);
        let b = Tensor::i32(vec![n, n], vec![2; n * n]);
        let c = Tensor::i32(vec![n, n], vec![3; n * n]);
        let out = exe("mm_i32_128").execute(&[a, b, c]).unwrap();
        // 3 + 1·2·128 = 259 everywhere
        assert!(out[0].data.as_i32().unwrap().iter().all(|&v| v == 259));
    }

    #[test]
    fn conv_matches_oracle() {
        let (h, w, p) = (128usize, 128usize, 4usize);
        let mut rng = XorShift64::new(13);
        let mut x = vec![0f32; (h + p - 1) * (w + p - 1)];
        let mut k = vec![0f32; p * p];
        rng.fill_f32(&mut x);
        rng.fill_f32(&mut k);
        let out = exe("conv2d_f32_128x4")
            .execute(&[
                Tensor::f32(vec![h + p - 1, w + p - 1], x.clone()),
                Tensor::f32(vec![p, p], k.clone()),
                Tensor::f32(vec![h, w], vec![0.0; h * w]),
            ])
            .unwrap();
        let want = verify::conv2d_ref(&x, &k, h, w, p, p);
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-3);
    }

    #[test]
    fn fir_matches_oracle() {
        let (n, taps) = (4096usize, 15usize);
        let mut rng = XorShift64::new(17);
        let mut x = vec![0f32; n + taps - 1];
        let mut h = vec![0f32; taps];
        rng.fill_f32(&mut x);
        rng.fill_f32(&mut h);
        let out = exe("fir_f32_4096x15")
            .execute(&[
                Tensor::f32(vec![n + taps - 1], x.clone()),
                Tensor::f32(vec![taps], h.clone()),
            ])
            .unwrap();
        let want = verify::fir_ref(&x, &h, n);
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-4);
    }

    #[test]
    fn fft_on_bit_reversed_rows_matches_host_fft() {
        let (b, n) = (64usize, 256usize);
        let mut rng = XorShift64::new(19);
        let mut re = vec![0f32; b * n];
        let mut im = vec![0f32; b * n];
        rng.fill_f32(&mut re);
        rng.fill_f32(&mut im);
        // the stub expects bit-reversed-order rows; permute on the host
        let bits = n.trailing_zeros();
        let rev: Vec<usize> = (0..n)
            .map(|i| ((i as u32).reverse_bits() >> (32 - bits)) as usize)
            .collect();
        let permute = |v: &[f32]| -> Vec<f32> {
            let mut out = vec![0f32; b * n];
            for row in 0..b {
                for (i, &s) in rev.iter().enumerate() {
                    out[row * n + i] = v[row * n + s];
                }
            }
            out
        };
        let out = exe("fft1d_f32_64x256")
            .execute(&[
                Tensor::f32(vec![b, n], permute(&re)),
                Tensor::f32(vec![b, n], permute(&im)),
            ])
            .unwrap();
        for row in 0..b {
            let mut hr = re[row * n..(row + 1) * n].to_vec();
            let mut hi = im[row * n..(row + 1) * n].to_vec();
            verify::fft_ref(&mut hr, &mut hi);
            let gr = &out[0].data.as_f32().unwrap()[row * n..(row + 1) * n];
            let gi = &out[1].data.as_f32().unwrap()[row * n..(row + 1) * n];
            assert!(verify::max_abs_diff(gr, &hr) < 1e-2, "row {row}");
            assert!(verify::max_abs_diff(gi, &hi) < 1e-2, "row {row}");
        }
    }

    #[test]
    fn complex_fir_agrees_with_real_decomposition() {
        let (n, taps) = (2048usize, 15usize);
        let mut rng = XorShift64::new(23);
        let mut xr = vec![0f32; n + taps - 1];
        let mut xi = vec![0f32; n + taps - 1];
        let mut hr = vec![0f32; taps];
        let mut hi = vec![0f32; taps];
        rng.fill_f32(&mut xr);
        rng.fill_f32(&mut xi);
        rng.fill_f32(&mut hr);
        rng.fill_f32(&mut hi);
        let out = exe("fir_cf32_2048x15")
            .execute(&[
                Tensor::f32(vec![n + taps - 1], xr.clone()),
                Tensor::f32(vec![n + taps - 1], xi.clone()),
                Tensor::f32(vec![taps], hr.clone()),
                Tensor::f32(vec![taps], hi.clone()),
            ])
            .unwrap();
        let rr = verify::fir_ref(&xr, &hr, n);
        let ii = verify::fir_ref(&xi, &hi, n);
        let yre: Vec<f32> = rr.iter().zip(&ii).map(|(a, b)| a - b).collect();
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &yre) < 1e-4);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn dwconv_matches_oracle() {
        let (c, h, p) = (8usize, 64usize, 3usize);
        let mut rng = XorShift64::new(29);
        let mut x = vec![0f32; c * (h + p - 1) * (h + p - 1)];
        let mut k = vec![0f32; c * p * p];
        rng.fill_f32(&mut x);
        rng.fill_f32(&mut k);
        let out = exe("dwconv2d_f32_8x64x3")
            .execute(&[
                Tensor::f32(vec![c, h + p - 1, h + p - 1], x.clone()),
                Tensor::f32(vec![c, p, p], k.clone()),
                Tensor::f32(vec![c, h, h], vec![0.0; c * h * h]),
            ])
            .unwrap();
        let want = verify::dw_conv2d_ref(&x, &k, c, h, h, p, p);
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-4);
    }

    #[test]
    fn trsv_matches_oracle() {
        let n = 256usize;
        let mut rng = XorShift64::new(31);
        let mut l = vec![0f32; n * n];
        let mut b = vec![0f32; n];
        rng.fill_f32(&mut l);
        rng.fill_f32(&mut b);
        // diagonally dominant system: keep the solve well-conditioned
        for i in 0..n {
            for j in 0..n {
                l[i * n + j] /= n as f32;
            }
            l[i * n + i] = 4.0 + l[i * n + i].abs();
        }
        let out = exe("trsv_f32_256")
            .execute(&[
                Tensor::f32(vec![n, n], l.clone()),
                Tensor::f32(vec![n], b.clone()),
            ])
            .unwrap();
        let want = verify::trsv_ref(&l, &b, n);
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-4);
    }

    #[test]
    fn stencil_matches_oracle_and_bakes_two_sweeps() {
        let n = 128usize;
        let mut rng = XorShift64::new(37);
        let mut a = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        let coef = [0.5f32, 0.125, 0.125, 0.125, 0.125];
        let out = exe("stencil2d_f32_2x128")
            .execute(&[
                Tensor::f32(vec![n, n], a.clone()),
                Tensor::f32(vec![5], coef.to_vec()),
            ])
            .unwrap();
        let want = verify::stencil2d_chain_ref(&a, n, n, 2, &coef);
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-4);
        assert_eq!(super::stencil_stages("stencil2d_f32_2x128"), 2);
        assert_eq!(super::stencil_stages("stencil2d_f32_4x64"), 4);
        assert_eq!(super::stencil_stages("weird"), 2);
    }

    #[test]
    fn ca_reduce_matches_slab_order_sum() {
        let (rep, n) = (4usize, 128usize);
        let mut rng = XorShift64::new(41);
        let mut parts = vec![0f32; rep * n * n];
        rng.fill_f32(&mut parts);
        let out = exe("ca_mm_f32_4x128")
            .execute(&[Tensor::f32(vec![rep, n, n], parts.clone())])
            .unwrap();
        // reference: fold the slabs in ascending order, bit-exactly
        let mut want = parts[..n * n].to_vec();
        for s in 1..rep {
            for (o, v) in want.iter_mut().zip(&parts[s * n * n..(s + 1) * n * n]) {
                *o += v;
            }
        }
        let got = out[0].data.as_f32().unwrap();
        assert!(got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn seidel_matches_oracle_and_differs_from_jacobi() {
        let n = 64usize;
        let mut rng = XorShift64::new(43);
        let mut a = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        let coef = [0.4f32, 0.2, 0.1, 0.15, 0.15];
        let out = exe("seidel2d_f32_2x64")
            .execute(&[
                Tensor::f32(vec![n, n], a.clone()),
                Tensor::f32(vec![5], coef.to_vec()),
            ])
            .unwrap();
        let want = verify::seidel2d_ref(&a, n, n, 2, &coef);
        assert!(verify::max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-4);
        // the fresh-south read distinguishes GS from the Jacobi stencil
        let jacobi = verify::stencil2d_chain_ref(&a, n, n, 2, &coef);
        assert!(verify::max_abs_diff(&want, &jacobi) > 1e-6);
        assert_eq!(super::stencil_stages("seidel2d_f32_2x64"), 2);
    }

    #[test]
    fn unknown_artifact_rejected() {
        let m = Manifest::builtin();
        let mut spec = m.get("mm_f32_128").unwrap().clone();
        spec.name = "weird_kernel".into();
        assert!(StubExecutable::compile(&spec).is_err());
    }
}
