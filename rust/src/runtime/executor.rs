//! Typed execution over artifacts: host tensors in, host tensors out.
//!
//! Both backends share the host [`Tensor`] type and the signature
//! validation; they differ only in what happens between validated inputs
//! and outputs. The PJRT backend marshals tensors into XLA literals and
//! decomposes the tuple-rooted result (the L2 lowering uses
//! `return_tuple=True`); the default stub backend dispatches straight to
//! the in-process kernel ([`super::stub`]).

use super::artifact::{ArtifactSpec, TensorSpec};
use super::client::Runtime;
use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use anyhow::Context;

/// Host tensor payload (f32 and i32 cover the functional-replay dtypes;
/// int8/int16/complex designs are timing-simulated and functionally
/// validated at the python layer — DESIGN.md §7).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            TensorData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host tensor: shape + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape,
            data: TensorData::F32(data),
        }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape,
            data: TensorData::I32(data),
        }
    }

    pub fn zeros_like_spec(spec: &TensorSpec) -> Result<Self> {
        let n = spec.elements();
        Ok(match spec.dtype.as_str() {
            "float32" => Tensor::f32(spec.shape.clone(), vec![0.0; n]),
            "int32" => Tensor::i32(spec.shape.clone(), vec![0; n]),
            other => bail!("unsupported replay dtype {other}"),
        })
    }

    /// Validate against a spec (shape + dtype).
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.shape == spec.shape
            && matches!(
                (&self.data, spec.dtype.as_str()),
                (TensorData::F32(_), "float32") | (TensorData::I32(_), "int32")
            )
    }
}

#[cfg(feature = "pjrt")]
impl Tensor {
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        let data = match spec.dtype.as_str() {
            "float32" => TensorData::F32(lit.to_vec::<f32>()?),
            "int32" => TensorData::I32(lit.to_vec::<i32>()?),
            other => bail!("unsupported replay dtype {other}"),
        };
        Ok(Tensor {
            shape: spec.shape.clone(),
            data,
        })
    }
}

/// Check an input list against an artifact signature (both backends).
fn validate_inputs(name: &str, spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
    }
    for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if !t.matches(s) {
            bail!(
                "{name}: input {i} mismatch: got shape {:?}, want {:?} {}",
                t.shape,
                s.shape,
                s.dtype
            );
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Execute an artifact with typed host tensors through the in-process
    /// stub kernel; validates the signature against the manifest on both
    /// sides.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_ref(name, &refs)
    }

    /// Borrowed-input variant of [`Runtime::run`]: the blocked replay
    /// driver slices tiles out of long-lived packed panels, and cloning
    /// every operand per round would double the host traffic the blocking
    /// plan exists to avoid.
    pub fn run_ref(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.spec(name)?.clone();
        validate_inputs(name, &spec, inputs)?;
        let exe = self.executable(name)?;
        let outputs = exe.execute_ref(inputs)?;
        if outputs.len() != spec.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                spec.outputs.len(),
                outputs.len()
            );
        }
        Ok(outputs)
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Execute an artifact with typed host tensors on the PJRT client;
    /// validates the signature against the manifest on both sides.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_ref(name, &refs)
    }

    /// Borrowed-input variant of [`Runtime::run`] (see the stub-backend
    /// doc comment; the PJRT marshalling copies into literals either way,
    /// but the shared signature keeps the replay driver backend-agnostic).
    pub fn run_ref(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.spec(name)?.clone();
        validate_inputs(name, &spec, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| Tensor::to_literal(t))
            .collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| Tensor::from_literal(lit, s))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify::{max_abs_diff, mm_ref};
    use crate::runtime::artifact::Manifest;
    use crate::util::rng::XorShift64;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn mm_artifact_matches_host_oracle() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        let n = 128;
        let mut rng = XorShift64::new(42);
        let mut a = vec![0f32; n * n];
        let mut b = vec![0f32; n * n];
        let mut c = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        rng.fill_f32(&mut c);
        let out = rt
            .run(
                "mm_f32_128",
                &[
                    Tensor::f32(vec![n, n], a.clone()),
                    Tensor::f32(vec![n, n], b.clone()),
                    Tensor::f32(vec![n, n], c.clone()),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let want = mm_ref(&a, &b, &c, n, n, n);
        assert!(max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-2);
    }

    /// The default stub backend must serve `run` with NO artifacts on
    /// disk: builtin manifest, validation, dispatch, output count.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_run_path_works_without_artifacts() {
        let mut rt = Runtime::with_builtin();
        let n = 128;
        let mut rng = XorShift64::new(77);
        let mut a = vec![0f32; n * n];
        let mut b = vec![0f32; n * n];
        let mut c = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        rng.fill_f32(&mut c);
        let out = rt
            .run(
                "mm_f32_128",
                &[
                    Tensor::f32(vec![n, n], a.clone()),
                    Tensor::f32(vec![n, n], b.clone()),
                    Tensor::f32(vec![n, n], c.clone()),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let want = mm_ref(&a, &b, &c, n, n, n);
        assert!(max_abs_diff(out[0].data.as_f32().unwrap(), &want) < 1e-2);

        // signature validation fires before dispatch
        let bad = Tensor::f32(vec![2, 2], vec![0.0; 4]);
        let err = rt
            .run("mm_f32_128", &[bad.clone(), bad.clone(), bad])
            .unwrap_err();
        assert!(err.to_string().contains("mismatch"));
        // wrong arity rejected too
        let ok = Tensor::f32(vec![n, n], vec![0.0; n * n]);
        assert!(rt.run("mm_f32_128", &[ok]).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        let bad = Tensor::f32(vec![2, 2], vec![0.0; 4]);
        let err = rt
            .run("mm_f32_128", &[bad.clone(), bad.clone(), bad])
            .unwrap_err();
        assert!(err.to_string().contains("mismatch"));
    }

    #[test]
    fn i32_artifact_roundtrip() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        let n = 128;
        let a = Tensor::i32(vec![n, n], vec![1; n * n]);
        let b = Tensor::i32(vec![n, n], vec![2; n * n]);
        let c = Tensor::i32(vec![n, n], vec![3; n * n]);
        let out = rt.run("mm_i32_128", &[a, b, c]).unwrap();
        // C' = 3 + 1·2·128 = 259 everywhere
        assert!(out[0].data.as_i32().unwrap().iter().all(|&v| v == 259));
    }
}
