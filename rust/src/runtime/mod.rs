//! Runtime bridge: load AOT-compiled HLO artifacts and execute them on
//! the PJRT CPU client from the rust hot path (python never runs here).
//!
//! [`artifact`] reads `artifacts/manifest.json` (produced once by
//! `python -m compile.aot`); [`client`] owns the PJRT client and an
//! executable cache; [`executor`] marshals typed host buffers in and out
//! of tuple-rooted executions.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::Runtime;
pub use executor::{Tensor, TensorData};
