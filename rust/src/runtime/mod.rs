//! Runtime bridge: execute the AOT-compiled graph-tile kernels from the
//! rust hot path (python never runs here).
//!
//! Two interchangeable backends sit behind one [`client::Runtime`] API:
//!
//! * **stub (default)** — [`stub`] is a deterministic in-process
//!   executor implementing every kernel of the L2 variant registry in
//!   host code. No JAX/XLA toolchain required; without on-disk artifacts
//!   the built-in signature set ([`artifact::Manifest::builtin`]) backs
//!   it, so `Runtime::new()` always succeeds.
//! * **PJRT (`pjrt` cargo feature)** — loads the HLO text artifacts
//!   produced by `python -m compile.aot` (`make artifacts`) and executes
//!   them on the PJRT CPU client via the `xla` crate.
//!
//! [`artifact`] reads `artifacts/manifest.json` (or synthesises the
//! builtin set); [`client`] owns the backend and an executable cache;
//! [`executor`] validates typed host buffers against the manifest
//! signature and marshals them in and out of executions.

pub mod artifact;
pub mod client;
pub mod executor;
pub mod stub;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::Runtime;
pub use executor::{Tensor, TensorData};
