//! Runtime client with a compiled-executable cache — two backends behind
//! one API.
//!
//! * **Default (no feature):** the deterministic in-process stub executor
//!   ([`super::stub`]). Artifacts resolve against the on-disk manifest
//!   when `make artifacts` has been run, else against the built-in
//!   signature set ([`Manifest::builtin`]) — so [`Runtime::new`] always
//!   succeeds and the functional-replay path needs no JAX/XLA toolchain.
//! * **`pjrt` feature:** the real bridge — parse the AOT-lowered HLO
//!   text, compile through the PJRT CPU client (`xla` crate) and cache
//!   the loaded executable per artifact name. Compiling an HLO module is
//!   expensive (hundreds of ms), so one [`Runtime`] per process and the
//!   L3 hot path only pays buffer transfer + execution.

use super::artifact::{ArtifactSpec, Manifest};
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(not(feature = "pjrt"))]
use super::stub::StubExecutable;

pub struct Runtime {
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    #[cfg(not(feature = "pjrt"))]
    cache: HashMap<String, StubExecutable>,
}

impl Runtime {
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Number of compiled executables resident.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Create over the default artifact directory; without on-disk
    /// artifacts the built-in signature set backs the stub executor.
    pub fn new() -> Result<Self> {
        Ok(Self {
            manifest: Manifest::load_or_builtin(Manifest::default_dir())?,
            cache: HashMap::new(),
        })
    }

    /// Create over an explicit artifact directory (must exist).
    pub fn with_dir(dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Self {
            manifest: Manifest::load(dir)?,
            cache: HashMap::new(),
        })
    }

    /// Create backed by the builtin signature set, ignoring any on-disk
    /// artifacts — fully deterministic, for tests and offline use.
    pub fn with_builtin() -> Self {
        Self {
            manifest: Manifest::builtin(),
            cache: HashMap::new(),
        }
    }

    /// Backend identification (the PJRT backend reports the platform the
    /// PJRT client runs on; the stub is an in-process CPU interpreter).
    pub fn platform(&self) -> String {
        "widesa-stub cpu (in-process)".to_string()
    }

    /// Resolve (or fetch from cache) an artifact's stub kernel.
    pub fn executable(&mut self, name: &str) -> Result<&StubExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.get(name)?.clone();
            let exe = StubExecutable::compile(&spec)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create over the default artifact directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(Manifest::default_dir())
    }

    pub fn with_dir(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.get(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path
                    .to_str()
                    .context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text for {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn compile_caches_executables() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        assert_eq!(rt.cached(), 0);
        rt.executable("mm_f32_128").unwrap();
        assert_eq!(rt.cached(), 1);
        rt.executable("mm_f32_128").unwrap();
        assert_eq!(rt.cached(), 1); // cache hit
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn unknown_artifact_errors() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        assert!(rt.executable("no_such_artifact").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_works_without_artifacts() {
        // No on-disk manifest needed: the builtin signature set backs it.
        let mut rt = Runtime::with_builtin();
        assert_eq!(rt.cached(), 0);
        rt.executable("mm_f32_128").unwrap();
        rt.executable("mm_f32_128").unwrap();
        assert_eq!(rt.cached(), 1);
        assert!(rt.platform().contains("stub"));
        assert!(rt.executable("no_such_artifact").is_err());
    }
}
