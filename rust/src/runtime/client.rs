//! PJRT client wrapper with a compiled-executable cache.
//!
//! One [`Runtime`] per process: compiling an HLO module is expensive
//! (hundreds of ms), so executables are compiled on first use and cached
//! by artifact name — the L3 hot path only pays buffer transfer +
//! execution.

use super::artifact::{ArtifactSpec, Manifest};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create over the default artifact directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(Manifest::default_dir())
    }

    pub fn with_dir(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.get(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path
                    .to_str()
                    .context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text for {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Number of compiled executables resident.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn compile_caches_executables() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        assert_eq!(rt.cached(), 0);
        rt.executable("mm_f32_128").unwrap();
        assert_eq!(rt.cached(), 1);
        rt.executable("mm_f32_128").unwrap();
        assert_eq!(rt.cached(), 1); // cache hit
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn unknown_artifact_errors() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        assert!(rt.executable("no_such_artifact").is_err());
    }
}
