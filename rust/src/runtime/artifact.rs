//! Artifact manifest: what the build-time python lowered, with shapes.

use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor's shape + dtype as recorded by aot.py.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: HLO file + signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn tensor_spec(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|x| x.as_u64().map(|u| u as usize).ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = v
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = parse(&text).map_err(|e| anyhow!("{e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            let hlo = entry
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing hlo"))?;
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(tensor_spec)
                    .collect()
            };
            let spec = ArtifactSpec {
                name: name.clone(),
                hlo_path: dir.join(hlo),
                inputs: specs("inputs")?,
                outputs: specs("outputs")?,
            };
            if !spec.hlo_path.exists() {
                bail!("{name}: HLO file {:?} missing", spec.hlo_path);
            }
            artifacts.insert(name.clone(), spec);
        }
        Ok(Self { artifacts, dir })
    }

    /// Load `<dir>/manifest.json` when it exists, else fall back to the
    /// [`Manifest::builtin`] signature set. This is what the default
    /// (stub-executor) runtime uses: it needs only tensor signatures, not
    /// HLO files, so a checkout that never ran `make artifacts` still gets
    /// a working functional-replay path.
    pub fn load_or_builtin(dir: impl AsRef<Path>) -> Result<Self> {
        if dir.as_ref().join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::builtin())
        }
    }

    /// The built-in artifact signature set: an exact mirror of the
    /// `VARIANTS` registry in `python/compile/model.py` (names, shapes and
    /// dtypes), with placeholder HLO paths. The stub executor implements
    /// every entry in host code; the PJRT backend never sees this manifest
    /// (it requires the real `make artifacts` output).
    pub fn builtin() -> Self {
        let dir = PathBuf::from("<builtin>");
        let ts = |shape: &[usize], dtype: &str| TensorSpec {
            shape: shape.to_vec(),
            dtype: dtype.to_string(),
        };
        let mut artifacts = BTreeMap::new();
        let mut add = |name: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
            artifacts.insert(
                name.to_string(),
                ArtifactSpec {
                    name: name.to_string(),
                    hlo_path: dir.join(format!("{name}.hlo.txt")),
                    inputs,
                    outputs,
                },
            );
        };
        // MM graph tiles (accumulate form): C' = C + A·B.
        for (name, n, dt) in [
            ("mm_f32_256", 256usize, "float32"),
            ("mm_f32_128", 128, "float32"),
            ("mm_i32_128", 128, "int32"),
        ] {
            add(
                name,
                vec![ts(&[n, n], dt), ts(&[n, n], dt), ts(&[n, n], dt)],
                vec![ts(&[n, n], dt)],
            );
        }
        // Conv2D graph tiles: halo-extended input, P×Q kernel, acc tile.
        for (name, h, p, dt) in [
            ("conv2d_f32_128x4", 128usize, 4usize, "float32"),
            ("conv2d_i32_64x4", 64, 4, "int32"),
        ] {
            add(
                name,
                vec![
                    ts(&[h + p - 1, h + p - 1], dt),
                    ts(&[p, p], dt),
                    ts(&[h, h], dt),
                ],
                vec![ts(&[h, h], dt)],
            );
        }
        // FIR graph tiles.
        add(
            "fir_f32_4096x15",
            vec![ts(&[4096 + 14], "float32"), ts(&[15], "float32")],
            vec![ts(&[4096], "float32")],
        );
        add(
            "fir_cf32_2048x15",
            vec![
                ts(&[2048 + 14], "float32"),
                ts(&[2048 + 14], "float32"),
                ts(&[15], "float32"),
                ts(&[15], "float32"),
            ],
            vec![ts(&[2048], "float32"), ts(&[2048], "float32")],
        );
        // FFT graph tile: 64 bit-reversed-order rows of length-256 FFTs.
        add(
            "fft1d_f32_64x256",
            vec![ts(&[64, 256], "float32"), ts(&[64, 256], "float32")],
            vec![ts(&[64, 256], "float32"), ts(&[64, 256], "float32")],
        );
        // Depthwise-conv graph tile: 8 channel groups of 64×64 output,
        // 3×3 per-group kernels over a halo-extended input block.
        add(
            "dwconv2d_f32_8x64x3",
            vec![
                ts(&[8, 66, 66], "float32"),
                ts(&[8, 3, 3], "float32"),
                ts(&[8, 64, 64], "float32"),
            ],
            vec![ts(&[8, 64, 64], "float32")],
        );
        // Triangular-solve graph tile: one 256-row forward-substitution
        // block (host k-chains the off-diagonal updates).
        add(
            "trsv_f32_256",
            vec![ts(&[256, 256], "float32"), ts(&[256], "float32")],
            vec![ts(&[256], "float32")],
        );
        // Stencil-chain graph tile: 2 Jacobi sweeps over a 128×128 grid
        // with 5 coefficients [centre, n, s, w, e].
        add(
            "stencil2d_f32_2x128",
            vec![ts(&[128, 128], "float32"), ts(&[5], "float32")],
            vec![ts(&[128, 128], "float32")],
        );
        // CA-MM reduction graph tile: 4 replica partial-C tiles summed in
        // slab order (the replication-axis merge of the 2.5D schedule).
        add(
            "ca_mm_f32_4x128",
            vec![ts(&[4, 128, 128], "float32")],
            vec![ts(&[128, 128], "float32")],
        );
        // Gauss–Seidel sweep-chain graph tile: 2 bottom-up in-place sweeps
        // over a 64×64 grid, coefficients [centre, s_new, s_old, w, e].
        add(
            "seidel2d_f32_2x64",
            vec![ts(&[64, 64], "float32"), ts(&[5], "float32")],
            vec![ts(&[64, 64], "float32")],
        );
        Self { artifacts, dir }
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    /// Default artifact directory: `$WIDESA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("WIDESA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest_when_built() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert!(m.artifacts.contains_key("mm_f32_128"));
        let a = m.get("mm_f32_128").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![128, 128]);
        assert_eq!(a.outputs[0].dtype, "float32");
        assert_eq!(a.inputs[0].elements(), 128 * 128);
    }

    #[test]
    fn missing_dir_fails_gracefully() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn builtin_mirrors_python_variant_registry() {
        let m = Manifest::builtin();
        assert_eq!(m.artifacts.len(), 13);
        for name in [
            "mm_f32_256",
            "mm_f32_128",
            "mm_i32_128",
            "conv2d_f32_128x4",
            "conv2d_i32_64x4",
            "fir_f32_4096x15",
            "fir_cf32_2048x15",
            "fft1d_f32_64x256",
            "dwconv2d_f32_8x64x3",
            "trsv_f32_256",
            "stencil2d_f32_2x128",
            "ca_mm_f32_4x128",
            "seidel2d_f32_2x64",
        ] {
            assert!(m.artifacts.contains_key(name), "{name} missing");
        }
        let mm = m.get("mm_f32_128").unwrap();
        assert_eq!(mm.inputs.len(), 3);
        assert_eq!(mm.outputs[0].shape, vec![128, 128]);
        assert_eq!(mm.inputs[0].elements(), 128 * 128);
    }

    #[test]
    fn load_or_builtin_falls_back_without_artifacts() {
        let m = Manifest::load_or_builtin("/nonexistent-dir-xyz").unwrap();
        assert!(m.artifacts.contains_key("fft1d_f32_64x256"));
    }
}
