//! Artifact manifest: what the build-time python lowered, with shapes.

use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor's shape + dtype as recorded by aot.py.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: HLO file + signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn tensor_spec(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|x| x.as_u64().map(|u| u as usize).ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = v
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = parse(&text).map_err(|e| anyhow!("{e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            let hlo = entry
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing hlo"))?;
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(tensor_spec)
                    .collect()
            };
            let spec = ArtifactSpec {
                name: name.clone(),
                hlo_path: dir.join(hlo),
                inputs: specs("inputs")?,
                outputs: specs("outputs")?,
            };
            if !spec.hlo_path.exists() {
                bail!("{name}: HLO file {:?} missing", spec.hlo_path);
            }
            artifacts.insert(name.clone(), spec);
        }
        Ok(Self { artifacts, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    /// Default artifact directory: `$WIDESA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("WIDESA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest_when_built() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert!(m.artifacts.contains_key("mm_f32_128"));
        let a = m.get("mm_f32_128").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![128, 128]);
        assert_eq!(a.outputs[0].dtype, "float32");
        assert_eq!(a.inputs[0].elements(), 128 * 128);
    }

    #[test]
    fn missing_dir_fails_gracefully() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
