//! The Vitis-compiler stand-in: place and route a mapped graph either
//! with WideSA's constraints (deterministic placement + Algorithm 1 +
//! router) or without (annealing from a random start) — the comparison
//! behind the paper's claim that systolic constraints make large designs
//! compile (CHARM "struggles to compile large designs on Vitis 2022.1").
//!
//! Timing here is span-derived: every stage runs under an
//! [`obs::trace::Span`](crate::obs::trace::Span) and [`StageTimings`] is
//! built from the values those spans measured. One measurement feeds
//! both the `stage_ms` protocol field and the Chrome-trace export, so
//! the two can never disagree (the duplication the observability PR
//! removed).

use crate::arch::vck5000::BoardConfig;
use crate::graph::builder::MappedGraph;
use crate::obs::trace::Span;
use crate::place_route::anneal::anneal;
use crate::place_route::constraints::ConstraintSet;
use crate::place_route::placement::{place, Placement};
use crate::place_route::router::route_all;
use crate::plio::assignment::assign;

/// Per-stage wall times of one P&R run, in milliseconds, as measured by
/// the `pnr.place` / `pnr.assign` / `pnr.route` spans (single source of
/// truth — the serve layer's `stage_ms` field and `--trace-out` exports
/// report the same numbers). On the annealing path the anneal is the
/// "place" stage; stages that never ran stay 0.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageTimings {
    pub place_ms: f64,
    pub assign_ms: f64,
    pub route_ms: f64,
}

#[derive(Debug, Clone)]
pub struct CompileOutcome {
    pub success: bool,
    pub wall_s: f64,
    /// Solver iterations (annealing) or 0 for the deterministic path.
    pub iterations: u64,
    pub placement: Option<Placement>,
    pub constraints: Option<ConstraintSet>,
    /// Peak per-boundary channel occupancy from routing, `None` when the
    /// flow failed before routing ran (the old `u32::MAX` failure
    /// sentinel is gone — aggregating it into a table is now a type
    /// error, not a silent overflow).
    pub max_congestion: Option<u32>,
    /// Where the wall time went (stages that never ran stay 0).
    pub stages: StageTimings,
}

/// Compile with WideSA constraints: deterministic placement, Algorithm 1
/// PLIO assignment, XY routing. Fails only if the design genuinely does
/// not fit.
pub fn compile(g: &MappedGraph, board: &BoardConfig) -> CompileOutcome {
    let pnr = Span::begin("pnr", "pnr");
    let place_span = Span::begin("pnr.place", "pnr");
    let placed = place(g, &board.array);
    let place_ms = place_span.end_ms();
    let Some(pl) = placed else {
        return CompileOutcome {
            success: false,
            wall_s: pnr.end_ms() / 1e3,
            iterations: 0,
            placement: None,
            constraints: None,
            max_congestion: None,
            stages: StageTimings {
                place_ms,
                ..Default::default()
            },
        };
    };
    let assign_span = Span::begin("pnr.assign", "pnr");
    let a = assign(
        g,
        &pl,
        &board.plio,
        board.array.rc_west,
        board.array.rc_east,
    );
    let assign_ms = assign_span.end_ms();
    let route_span = Span::begin("pnr.route", "pnr");
    let routing = route_all(
        g,
        &pl,
        &a.columns,
        board.array.cols,
        board.array.rc_west,
        board.array.rc_east,
    );
    let route_ms = route_span.end_ms();
    let cs = ConstraintSet::from_design(g, &pl, &a.columns);
    CompileOutcome {
        success: a.feasible && routing.success && pl.shared_buffers_adjacent(g, &board.array),
        wall_s: pnr.end_ms() / 1e3,
        iterations: 0,
        placement: Some(pl),
        constraints: Some(cs),
        max_congestion: Some(routing.max_west.max(routing.max_east)),
        stages: StageTimings {
            place_ms,
            assign_ms,
            route_ms,
        },
    }
}

/// Compile without constraints: annealing placement under an iteration
/// budget (the raw-ILP stand-in), then Algorithm-1-free column packing.
/// The anneal runs as the `pnr.place` span (it *is* this path's
/// placement stage).
pub fn compile_unconstrained(
    g: &MappedGraph,
    board: &BoardConfig,
    seed: u64,
    max_iters: u64,
) -> CompileOutcome {
    let pnr = Span::begin("pnr", "pnr");
    let place_span = Span::begin("pnr.place", "pnr");
    let r = anneal(g, &board.array, seed, max_iters);
    let place_ms = place_span.end_ms();
    if !r.converged {
        return CompileOutcome {
            success: false,
            wall_s: pnr.end_ms() / 1e3,
            iterations: r.iterations,
            placement: Some(r.placement),
            constraints: None,
            max_congestion: None,
            stages: StageTimings {
                place_ms,
                ..Default::default()
            },
        };
    }
    let assign_span = Span::begin("pnr.assign", "pnr");
    let a = assign(
        g,
        &r.placement,
        &board.plio,
        board.array.rc_west,
        board.array.rc_east,
    );
    let assign_ms = assign_span.end_ms();
    let route_span = Span::begin("pnr.route", "pnr");
    let routing = route_all(
        g,
        &r.placement,
        &a.columns,
        board.array.cols,
        board.array.rc_west,
        board.array.rc_east,
    );
    let route_ms = route_span.end_ms();
    CompileOutcome {
        success: a.feasible && routing.success,
        wall_s: pnr.end_ms() / 1e3,
        iterations: r.iterations,
        placement: Some(r.placement),
        constraints: None,
        max_congestion: Some(routing.max_west.max(routing.max_east)),
        stages: StageTimings {
            place_ms,
            assign_ms,
            route_ms,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build;
    use crate::graph::packet::merge_ports;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn graph(cap: u64) -> (MappedGraph, BoardConfig) {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(8192, 8192, 8192, DType::F32), &board, &cons).unwrap();
        let model = CostModel::new(board.clone());
        let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
        (g, board)
    }

    #[test]
    fn constrained_compile_succeeds_at_400() {
        let (g, board) = graph(400);
        let out = compile(&g, &board);
        assert!(out.success);
        assert!(out.constraints.is_some());
    }

    #[test]
    fn constrained_is_fast() {
        let (g, board) = graph(400);
        let out = compile(&g, &board);
        assert!(out.wall_s < 5.0, "constrained compile took {}s", out.wall_s);
    }

    #[test]
    fn stage_timings_partition_the_wall() {
        let (g, board) = graph(400);
        let out = compile(&g, &board);
        let s = out.stages;
        assert!(s.place_ms >= 0.0 && s.assign_ms >= 0.0 && s.route_ms >= 0.0);
        // the three stages (plus constraint extraction) make up the wall
        let sum_s = (s.place_ms + s.assign_ms + s.route_ms) / 1e3;
        assert!(
            sum_s <= out.wall_s + 1e-3,
            "stage sum {sum_s}s exceeds wall {}s",
            out.wall_s
        );
    }

    /// Regression for the StageTimings-duplication fix: with tracing on,
    /// the spans a compile emits carry exactly the durations that landed
    /// in `StageTimings` — there is no second clock to drift.
    #[test]
    fn stage_timings_match_recorded_spans() {
        use crate::obs::trace;
        let (g, board) = graph(400);
        trace::set_enabled(true);
        let id = trace::next_trace_id();
        let out = {
            let _ctx = trace::TraceCtx::set(id);
            compile(&g, &board)
        };
        let evs: Vec<_> = trace::snapshot_events()
            .into_iter()
            .filter(|e| e.trace_id == id)
            .collect();
        let dur_ms = |name: &str| -> f64 {
            let e = evs
                .iter()
                .find(|e| e.name == name)
                .unwrap_or_else(|| panic!("span {name} recorded"));
            e.dur_us as f64 / 1e3
        };
        // span µs are the truncated-integer view of the same measurement
        // StageTimings stores as f64 ms: equal to within 1 µs + rounding
        let close = |a: f64, b: f64| (a - b).abs() <= 2e-3;
        assert!(close(dur_ms("pnr.place"), out.stages.place_ms));
        assert!(close(dur_ms("pnr.assign"), out.stages.assign_ms));
        assert!(close(dur_ms("pnr.route"), out.stages.route_ms));
        assert!(close(dur_ms("pnr"), out.wall_s * 1e3));
        // nesting: children sit inside the pnr parent interval
        let parent = evs.iter().find(|e| e.name == "pnr").unwrap();
        for child in ["pnr.place", "pnr.assign", "pnr.route"] {
            let c = evs.iter().find(|e| e.name == child).unwrap();
            assert!(c.ts_us >= parent.ts_us);
            // +2 µs slack: ts and dur truncate to whole µs independently
            assert!(c.ts_us + c.dur_us <= parent.ts_us + parent.dur_us + 2);
        }
    }

    #[test]
    fn unconstrained_fails_at_400_within_budget() {
        let (g, board) = graph(400);
        let out = compile_unconstrained(&g, &board, 3, 20_000);
        assert!(!out.success, "unconstrained should not converge at 400 AIEs in 20k iters");
    }

    #[test]
    fn unconstrained_succeeds_small() {
        let (g, board) = graph(16);
        let out = compile_unconstrained(&g, &board, 3, 2_000_000);
        assert!(out.success, "16-core design should anneal to legality");
    }
}
