//! Unconstrained placement baseline: simulated annealing over random
//! placements — the stand-in for the raw ILP flow the Vitis compiler
//! runs when no constraints are provided (§II-A-2: "as the design scale
//! increases ... finding a legal solution efficiently becomes challenging
//! for the solvers"). E5 compares this against the constraint-guided
//! deterministic placement.
//!
//! Moves are evaluated *incrementally*: only the edges incident to the
//! moved (and swapped) nodes are re-scored, so one iteration is O(degree)
//! rather than O(edges) — the difference between simulating thousands and
//! millions of solver iterations in the E5 ablation.

use crate::arch::array::{AieArray, Coord};
use crate::graph::builder::MappedGraph;
use crate::graph::edge::EdgeKind;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use crate::util::rng::XorShift64;
use std::collections::HashMap;

/// Annealing outcome.
#[derive(Debug, Clone)]
pub struct AnnealResult {
    pub placement: Placement,
    /// Shared-buffer edges whose endpoints are not neighbours (must be 0
    /// for a legal design).
    pub violations: usize,
    pub iterations: u64,
    pub converged: bool,
}

/// Penalty per non-adjacent shared-buffer edge.
const VIOLATION_PENALTY: u64 = 100;

fn edge_cost(a: Coord, b: Coord, array: &AieArray) -> (u64, bool) {
    let d = a.manhattan(b) as u64;
    let violated = !array.shares_buffer(a, b);
    (d + if violated { VIOLATION_PENALTY } else { 0 }, violated)
}

/// Full-cost scan (initialisation and verification).
fn full_cost(
    edges: &[(NodeId, NodeId)],
    coords: &HashMap<NodeId, Coord>,
    array: &AieArray,
) -> (u64, usize) {
    let mut total = 0u64;
    let mut violations = 0usize;
    for &(s, d) in edges {
        let (c, v) = edge_cost(coords[&s], coords[&d], array);
        total += c;
        violations += v as usize;
    }
    (total, violations)
}

/// Anneal a placement from a random start. `max_iters` bounds runtime;
/// convergence = zero violations.
pub fn anneal(g: &MappedGraph, array: &AieArray, seed: u64, max_iters: u64) -> AnnealResult {
    let mut rng = XorShift64::new(seed);
    let aies: Vec<NodeId> = g.aie_nodes().map(|n| n.id).collect();
    let slots: Vec<Coord> = array.coords().collect();
    assert!(aies.len() <= slots.len(), "design larger than array");

    let shared_edges: Vec<(NodeId, NodeId)> = g
        .edges
        .iter()
        .filter(|e| e.kind == EdgeKind::SharedBuffer)
        .map(|e| (e.src, e.dst))
        .collect();
    // incidence: node → indices into shared_edges
    let mut incident: HashMap<NodeId, Vec<usize>> = HashMap::new();
    for (i, &(s, d)) in shared_edges.iter().enumerate() {
        incident.entry(s).or_default().push(i);
        incident.entry(d).or_default().push(i);
    }

    // random initial assignment: shuffle slots
    let mut perm: Vec<usize> = (0..slots.len()).collect();
    for i in (1..perm.len()).rev() {
        let j = rng.gen_range(i as u64 + 1) as usize;
        perm.swap(i, j);
    }
    let mut coords: HashMap<NodeId, Coord> = aies
        .iter()
        .enumerate()
        .map(|(k, &id)| (id, slots[perm[k]]))
        .collect();
    let mut slot_of: HashMap<Coord, NodeId> = coords.iter().map(|(&n, &c)| (c, n)).collect();

    let (mut cur_cost, mut cur_viol) = full_cost(&shared_edges, &coords, array);
    let mut temp = 50.0f64;
    let mut iters = 0u64;
    let mut affected: Vec<usize> = Vec::with_capacity(16);

    while iters < max_iters && cur_viol > 0 {
        iters += 1;
        // Move selection: mostly min-conflicts repair (move one endpoint
        // of a violated edge next to its partner), occasionally a random
        // perturbation to escape local minima.
        let (n, to) = if rng.gen_f64() < 0.8 && !shared_edges.is_empty() {
            let start = rng.gen_range(shared_edges.len() as u64) as usize;
            let mut pick = None;
            for k in 0..shared_edges.len() {
                let (s, d) = shared_edges[(start + k) % shared_edges.len()];
                if !array.shares_buffer(coords[&s], coords[&d]) {
                    pick = Some((s, d));
                    break;
                }
            }
            match pick {
                Some((s, d)) => {
                    let nbs = array.neighbours(coords[&d]);
                    let to = nbs[rng.gen_range(nbs.len() as u64) as usize];
                    (s, to)
                }
                None => {
                    let n = aies[rng.gen_range(aies.len() as u64) as usize];
                    (n, slots[rng.gen_range(slots.len() as u64) as usize])
                }
            }
        } else {
            let n = aies[rng.gen_range(aies.len() as u64) as usize];
            (n, slots[rng.gen_range(slots.len() as u64) as usize])
        };
        let from = coords[&n];
        if from == to {
            continue;
        }
        let other = slot_of.get(&to).copied();

        // affected edges: incident to n and (if swapping) to other
        affected.clear();
        if let Some(v) = incident.get(&n) {
            affected.extend_from_slice(v);
        }
        if let Some(o) = other {
            if let Some(v) = incident.get(&o) {
                affected.extend_from_slice(v);
            }
        }
        affected.sort_unstable();
        affected.dedup();

        let score = |coords: &HashMap<NodeId, Coord>| -> (u64, i64) {
            let mut c = 0u64;
            let mut v = 0i64;
            for &i in &affected {
                let (s, d) = shared_edges[i];
                let (ec, ev) = edge_cost(coords[&s], coords[&d], array);
                c += ec;
                v += ev as i64;
            }
            (c, v)
        };
        let (before_c, before_v) = score(&coords);

        // apply
        coords.insert(n, to);
        slot_of.insert(to, n);
        slot_of.remove(&from);
        if let Some(o) = other {
            coords.insert(o, from);
            slot_of.insert(from, o);
        }

        let (after_c, after_v) = score(&coords);
        let candidate_cost = (cur_cost + after_c).saturating_sub(before_c);
        let accept = candidate_cost <= cur_cost
            || rng.gen_f64() < (-((candidate_cost - cur_cost) as f64) / temp.max(1e-3)).exp();
        if accept {
            cur_cost = candidate_cost;
            cur_viol = (cur_viol as i64 + after_v - before_v) as usize;
        } else {
            // revert
            coords.insert(n, from);
            slot_of.insert(from, n);
            slot_of.remove(&to);
            if let Some(o) = other {
                coords.insert(o, to);
                slot_of.insert(to, o);
            } else {
                slot_of.remove(&to);
            }
        }
        temp *= 0.9995;
    }
    // exact final verification
    let (_, final_viol) = full_cost(&shared_edges, &coords, array);
    AnnealResult {
        placement: Placement { coords },
        violations: final_viol,
        iterations: iters,
        converged: final_viol == 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn graph(cap: u64) -> MappedGraph {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(2048, 2048, 2048, DType::F32), &board, &cons).unwrap();
        build(&cand, &CostModel::new(board))
    }

    #[test]
    fn small_design_converges() {
        let g = graph(16);
        let r = anneal(&g, &AieArray::default(), 1, 2_000_000);
        assert!(r.converged, "violations left: {}", r.violations);
        assert!(r.placement.shared_buffers_adjacent(&g, &AieArray::default()));
    }

    #[test]
    fn large_design_struggles_within_small_budget() {
        // The paper's observation: high utilisation makes unconstrained
        // P&R hard. At 400 AIEs the annealer should NOT converge within a
        // budget that is ample for the 16-core design.
        let g = graph(400);
        let r = anneal(&g, &AieArray::default(), 1, 50_000);
        assert!(!r.converged, "unexpectedly converged in 50k iters");
    }

    #[test]
    fn deterministic_for_seed() {
        let g = graph(16);
        let a = anneal(&g, &AieArray::default(), 7, 100_000);
        let b = anneal(&g, &AieArray::default(), 7, 100_000);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.violations, b.violations);
    }

    #[test]
    fn incremental_cost_matches_full_scan() {
        // run a short anneal and verify the tracked violation count via
        // the exact final recount (converged flag is recomputed exactly)
        let g = graph(64);
        let r = anneal(&g, &AieArray::default(), 5, 10_000);
        // violations from the struct must equal a fresh full scan
        let edges: Vec<_> = g
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::SharedBuffer)
            .map(|e| (e.src, e.dst))
            .collect();
        let (_, v) = full_cost(&edges, &r.placement.coords, &AieArray::default());
        assert_eq!(v, r.violations);
    }
}
