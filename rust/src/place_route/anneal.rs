//! Unconstrained placement baseline: simulated annealing over random
//! placements — the stand-in for the raw ILP flow the Vitis compiler
//! runs when no constraints are provided (§II-A-2: "as the design scale
//! increases ... finding a legal solution efficiently becomes challenging
//! for the solvers"). E5 compares this against the constraint-guided
//! deterministic placement.
//!
//! Moves are evaluated *incrementally*: only the edges incident to the
//! moved (and swapped) nodes are re-scored, so one iteration is O(degree)
//! rather than O(edges) — the difference between simulating thousands and
//! millions of solver iterations in the E5 ablation.
//!
//! The hot path is fully dense-indexed (node ids are contiguous vector
//! indices — the builder contract asserted by
//! [`MappedGraph::node_ids_are_dense`]): coordinates live in a flat
//! `Vec<Coord>` keyed by `NodeId`, slot occupancy in a flat
//! `row * cols + col` grid, edge incidence in a CSR (offsets + flat
//! edge-index array), and the set of currently-violated edges in a
//! [`DenseBitSet`] worklist maintained incrementally with O(1) membership
//! updates — min-conflicts move selection queries it with a word-skipping
//! circular scan instead of walking the edge list through two hash
//! lookups per step. Every RNG draw and accept decision is identical to
//! the retained HashMap implementation (`legacy::anneal_legacy`), so
//! results are bit-identical per seed (same iterations, violations and
//! final placement) — `make pnr-smoke` gates both the equivalence and a
//! ≥2× iteration-throughput win on the E5 400-AIE workload.

use crate::arch::array::{AieArray, Coord};
use crate::graph::builder::MappedGraph;
use crate::graph::edge::EdgeKind;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use crate::util::bitset::DenseBitSet;
use crate::util::rng::XorShift64;

/// Annealing outcome.
#[derive(Debug, Clone)]
pub struct AnnealResult {
    pub placement: Placement,
    /// Shared-buffer edges whose endpoints are not neighbours (must be 0
    /// for a legal design).
    pub violations: usize,
    pub iterations: u64,
    pub converged: bool,
}

/// Penalty per non-adjacent shared-buffer edge.
const VIOLATION_PENALTY: u64 = 100;

fn edge_cost(a: Coord, b: Coord, array: &AieArray) -> (u64, bool) {
    let d = a.manhattan(b) as u64;
    let violated = !array.shares_buffer(a, b);
    (d + if violated { VIOLATION_PENALTY } else { 0 }, violated)
}

/// Shared-buffer edges of a graph, in edge order.
fn shared_edges(g: &MappedGraph) -> Vec<(NodeId, NodeId)> {
    g.edges
        .iter()
        .filter(|e| e.kind == EdgeKind::SharedBuffer)
        .map(|e| (e.src, e.dst))
        .collect()
}

/// CSR incidence: for each node, the indices of shared-buffer edges
/// touching it — offsets + one flat edge-index array instead of a
/// `HashMap<NodeId, Vec<usize>>` of little heap allocations.
struct Incidence {
    offsets: Vec<u32>,
    edge_ids: Vec<u32>,
}

impl Incidence {
    fn build(num_nodes: usize, edges: &[(NodeId, NodeId)]) -> Self {
        let mut offsets = vec![0u32; num_nodes + 1];
        for &(s, d) in edges {
            offsets[s + 1] += 1;
            offsets[d + 1] += 1;
        }
        for i in 1..offsets.len() {
            offsets[i] += offsets[i - 1];
        }
        let mut cursor = offsets.clone();
        let mut edge_ids = vec![0u32; offsets[num_nodes] as usize];
        for (i, &(s, d)) in edges.iter().enumerate() {
            edge_ids[cursor[s] as usize] = i as u32;
            cursor[s] += 1;
            edge_ids[cursor[d] as usize] = i as u32;
            cursor[d] += 1;
        }
        Self { offsets, edge_ids }
    }

    fn of(&self, n: NodeId) -> &[u32] {
        &self.edge_ids[self.offsets[n] as usize..self.offsets[n + 1] as usize]
    }
}

/// Anneal a placement from a random start. `max_iters` bounds runtime;
/// convergence = zero violations.
pub fn anneal(g: &MappedGraph, array: &AieArray, seed: u64, max_iters: u64) -> AnnealResult {
    debug_assert!(g.node_ids_are_dense(), "builder must keep node ids dense");
    let mut rng = XorShift64::new(seed);
    let aies: Vec<NodeId> = g.aie_nodes().map(|n| n.id).collect();
    let slots: Vec<Coord> = array.coords().collect();
    assert!(aies.len() <= slots.len(), "design larger than array");

    let edges = shared_edges(g);
    let n_edges = edges.len();
    let incidence = Incidence::build(g.nodes.len(), &edges);
    // per-slot neighbour lists, exactly AieArray::neighbours order (one
    // allocation up front instead of one per min-conflicts iteration)
    let neighbours: Vec<Vec<Coord>> = slots.iter().map(|&c| array.neighbours(c)).collect();
    let slot_index = |c: Coord| (c.row * array.cols + c.col) as usize;

    // random initial assignment: shuffle slots
    let mut perm: Vec<usize> = (0..slots.len()).collect();
    for i in (1..perm.len()).rev() {
        let j = rng.gen_range(i as u64 + 1) as usize;
        perm.swap(i, j);
    }
    let mut coords: Vec<Coord> = vec![Coord::new(0, 0); g.nodes.len()];
    let mut slot_of: Vec<Option<NodeId>> = vec![None; slots.len()];
    for (k, &id) in aies.iter().enumerate() {
        let c = slots[perm[k]];
        coords[id] = c;
        slot_of[slot_index(c)] = Some(id);
    }

    // initial exact cost + violated-edge worklist
    let mut violated = DenseBitSet::new(n_edges);
    let mut cur_cost = 0u64;
    let mut cur_viol = 0usize;
    for (i, &(s, d)) in edges.iter().enumerate() {
        let (c, v) = edge_cost(coords[s], coords[d], array);
        cur_cost += c;
        if v {
            violated.set(i, true);
            cur_viol += 1;
        }
    }

    let mut temp = 50.0f64;
    let mut iters = 0u64;
    let mut affected: Vec<u32> = Vec::with_capacity(16);
    // epoch stamps dedupe the affected-edge list without a per-iteration
    // sort (sums over the set are order-independent)
    let mut stamp: Vec<u64> = vec![0; n_edges];
    let mut epoch = 0u64;

    while iters < max_iters && cur_viol > 0 {
        iters += 1;
        // Move selection: mostly min-conflicts repair (move one endpoint
        // of a violated edge next to its partner), occasionally a random
        // perturbation to escape local minima. The worklist query picks
        // the same edge the legacy circular edge-list scan would.
        let (n, to) = if rng.gen_f64() < 0.8 && n_edges > 0 {
            let start = rng.gen_range(n_edges as u64) as usize;
            match violated.first_set_circular(start) {
                Some(i) => {
                    let (s, d) = edges[i];
                    let nbs = &neighbours[slot_index(coords[d])];
                    let to = nbs[rng.gen_range(nbs.len() as u64) as usize];
                    (s, to)
                }
                None => {
                    let n = aies[rng.gen_range(aies.len() as u64) as usize];
                    (n, slots[rng.gen_range(slots.len() as u64) as usize])
                }
            }
        } else {
            let n = aies[rng.gen_range(aies.len() as u64) as usize];
            (n, slots[rng.gen_range(slots.len() as u64) as usize])
        };
        let from = coords[n];
        if from == to {
            continue;
        }
        let (from_slot, to_slot) = (slot_index(from), slot_index(to));
        let other = slot_of[to_slot];

        // affected edges: incident to n and (if swapping) to other
        epoch += 1;
        affected.clear();
        for &e in incidence.of(n) {
            if stamp[e as usize] != epoch {
                stamp[e as usize] = epoch;
                affected.push(e);
            }
        }
        if let Some(o) = other {
            for &e in incidence.of(o) {
                if stamp[e as usize] != epoch {
                    stamp[e as usize] = epoch;
                    affected.push(e);
                }
            }
        }

        let score = |coords: &[Coord]| -> (u64, i64) {
            let mut c = 0u64;
            let mut v = 0i64;
            for &i in &affected {
                let (s, d) = edges[i as usize];
                let (ec, ev) = edge_cost(coords[s], coords[d], array);
                c += ec;
                v += ev as i64;
            }
            (c, v)
        };
        let (before_c, before_v) = score(&coords[..]);

        // apply
        coords[n] = to;
        slot_of[to_slot] = Some(n);
        slot_of[from_slot] = None;
        if let Some(o) = other {
            coords[o] = from;
            slot_of[from_slot] = Some(o);
        }

        let (after_c, after_v) = score(&coords[..]);
        let candidate_cost = (cur_cost + after_c).saturating_sub(before_c);
        let accept = candidate_cost <= cur_cost
            || rng.gen_f64() < (-((candidate_cost - cur_cost) as f64) / temp.max(1e-3)).exp();
        if accept {
            cur_cost = candidate_cost;
            cur_viol = (cur_viol as i64 + after_v - before_v) as usize;
            // refresh worklist membership for the touched edges (only
            // edges incident to the moved nodes can change state)
            for &i in &affected {
                let (s, d) = edges[i as usize];
                violated.set(i as usize, !array.shares_buffer(coords[s], coords[d]));
            }
        } else {
            // revert: one grid write per slot — `slot_of[to_slot] = other`
            // both restores a swap partner and vacates an empty target
            // (the legacy HashMap version needed a redundant second
            // `remove(&to)` here)
            coords[n] = from;
            slot_of[from_slot] = Some(n);
            slot_of[to_slot] = other;
            if let Some(o) = other {
                coords[o] = to;
            }
        }
        temp *= 0.9995;
    }
    // The incremental count is exact by construction (every touched edge
    // is re-scored), so the legacy O(E) final recount is replaced by a
    // debug-build assertion.
    #[cfg(debug_assertions)]
    {
        let exact = edges
            .iter()
            .filter(|&&(s, d)| !array.shares_buffer(coords[s], coords[d]))
            .count();
        debug_assert_eq!(cur_viol, exact, "incremental violation count drifted");
        debug_assert_eq!(violated.count(), cur_viol, "worklist drifted");
    }
    let mut placement = Placement::with_grid(array.rows, array.cols);
    for &id in &aies {
        placement.insert(id, coords[id]);
    }
    AnnealResult {
        placement,
        violations: cur_viol,
        iterations: iters,
        converged: cur_viol == 0,
    }
}

/// The retained pre-dense implementation — three `HashMap`s and an O(E)
/// violated-edge scan per iteration. Kept verbatim as the baseline the
/// `bench_compile` speedup gate measures against and the oracle the
/// equivalence corpus compares bit-for-bit (`tests/pnr_equivalence.rs`,
/// feature `legacy-hash-pnr`; a smaller in-crate corpus runs under plain
/// `cargo test`). Not part of the compile pipeline.
#[cfg(any(test, feature = "legacy-hash-pnr"))]
pub mod legacy {
    use super::*;
    use std::collections::HashMap;

    /// Full-cost scan (initialisation and verification).
    fn full_cost(
        edges: &[(NodeId, NodeId)],
        coords: &HashMap<NodeId, Coord>,
        array: &AieArray,
    ) -> (u64, usize) {
        let mut total = 0u64;
        let mut violations = 0usize;
        for &(s, d) in edges {
            let (c, v) = edge_cost(coords[&s], coords[&d], array);
            total += c;
            violations += v as usize;
        }
        (total, violations)
    }

    /// The original HashMap-based annealer, bit-identical per seed to
    /// [`super::anneal`].
    pub fn anneal_legacy(
        g: &MappedGraph,
        array: &AieArray,
        seed: u64,
        max_iters: u64,
    ) -> AnnealResult {
        let mut rng = XorShift64::new(seed);
        let aies: Vec<NodeId> = g.aie_nodes().map(|n| n.id).collect();
        let slots: Vec<Coord> = array.coords().collect();
        assert!(aies.len() <= slots.len(), "design larger than array");

        let shared_edges = super::shared_edges(g);
        // incidence: node → indices into shared_edges
        let mut incident: HashMap<NodeId, Vec<usize>> = HashMap::new();
        for (i, &(s, d)) in shared_edges.iter().enumerate() {
            incident.entry(s).or_default().push(i);
            incident.entry(d).or_default().push(i);
        }

        // random initial assignment: shuffle slots
        let mut perm: Vec<usize> = (0..slots.len()).collect();
        for i in (1..perm.len()).rev() {
            let j = rng.gen_range(i as u64 + 1) as usize;
            perm.swap(i, j);
        }
        let mut coords: HashMap<NodeId, Coord> = aies
            .iter()
            .enumerate()
            .map(|(k, &id)| (id, slots[perm[k]]))
            .collect();
        let mut slot_of: HashMap<Coord, NodeId> =
            coords.iter().map(|(&n, &c)| (c, n)).collect();

        let (mut cur_cost, mut cur_viol) = full_cost(&shared_edges, &coords, array);
        let mut temp = 50.0f64;
        let mut iters = 0u64;
        let mut affected: Vec<usize> = Vec::with_capacity(16);

        while iters < max_iters && cur_viol > 0 {
            iters += 1;
            let (n, to) = if rng.gen_f64() < 0.8 && !shared_edges.is_empty() {
                let start = rng.gen_range(shared_edges.len() as u64) as usize;
                let mut pick = None;
                for k in 0..shared_edges.len() {
                    let (s, d) = shared_edges[(start + k) % shared_edges.len()];
                    if !array.shares_buffer(coords[&s], coords[&d]) {
                        pick = Some((s, d));
                        break;
                    }
                }
                match pick {
                    Some((s, d)) => {
                        let nbs = array.neighbours(coords[&d]);
                        let to = nbs[rng.gen_range(nbs.len() as u64) as usize];
                        (s, to)
                    }
                    None => {
                        let n = aies[rng.gen_range(aies.len() as u64) as usize];
                        (n, slots[rng.gen_range(slots.len() as u64) as usize])
                    }
                }
            } else {
                let n = aies[rng.gen_range(aies.len() as u64) as usize];
                (n, slots[rng.gen_range(slots.len() as u64) as usize])
            };
            let from = coords[&n];
            if from == to {
                continue;
            }
            let other = slot_of.get(&to).copied();

            affected.clear();
            if let Some(v) = incident.get(&n) {
                affected.extend_from_slice(v);
            }
            if let Some(o) = other {
                if let Some(v) = incident.get(&o) {
                    affected.extend_from_slice(v);
                }
            }
            affected.sort_unstable();
            affected.dedup();

            let score = |coords: &HashMap<NodeId, Coord>| -> (u64, i64) {
                let mut c = 0u64;
                let mut v = 0i64;
                for &i in &affected {
                    let (s, d) = shared_edges[i];
                    let (ec, ev) = edge_cost(coords[&s], coords[&d], array);
                    c += ec;
                    v += ev as i64;
                }
                (c, v)
            };
            let (before_c, before_v) = score(&coords);

            coords.insert(n, to);
            slot_of.insert(to, n);
            slot_of.remove(&from);
            if let Some(o) = other {
                coords.insert(o, from);
                slot_of.insert(from, o);
            }

            let (after_c, after_v) = score(&coords);
            let candidate_cost = (cur_cost + after_c).saturating_sub(before_c);
            let accept = candidate_cost <= cur_cost
                || rng.gen_f64()
                    < (-((candidate_cost - cur_cost) as f64) / temp.max(1e-3)).exp();
            if accept {
                cur_cost = candidate_cost;
                cur_viol = (cur_viol as i64 + after_v - before_v) as usize;
            } else {
                coords.insert(n, from);
                slot_of.insert(from, n);
                slot_of.remove(&to);
                if let Some(o) = other {
                    coords.insert(o, to);
                    slot_of.insert(to, o);
                }
            }
            temp *= 0.9995;
        }
        // exact final verification
        let (_, final_viol) = full_cost(&shared_edges, &coords, array);
        let mut placement = Placement::with_grid(array.rows, array.cols);
        for (&n, &c) in &coords {
            placement.insert(n, c);
        }
        AnnealResult {
            placement,
            violations: final_viol,
            iterations: iters,
            converged: final_viol == 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;
    use std::collections::BTreeMap;

    fn graph(cap: u64) -> MappedGraph {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(2048, 2048, 2048, DType::F32), &board, &cons).unwrap();
        build(&cand, &CostModel::new(board))
    }

    #[test]
    fn small_design_converges() {
        let g = graph(16);
        let r = anneal(&g, &AieArray::default(), 1, 2_000_000);
        assert!(r.converged, "violations left: {}", r.violations);
        assert!(r.placement.shared_buffers_adjacent(&g, &AieArray::default()));
    }

    #[test]
    fn large_design_struggles_within_small_budget() {
        // The paper's observation: high utilisation makes unconstrained
        // P&R hard. At 400 AIEs the annealer should NOT converge within a
        // budget that is ample for the 16-core design.
        let g = graph(400);
        let r = anneal(&g, &AieArray::default(), 1, 50_000);
        assert!(!r.converged, "unexpectedly converged in 50k iters");
    }

    #[test]
    fn deterministic_for_seed() {
        let g = graph(16);
        let a = anneal(&g, &AieArray::default(), 7, 100_000);
        let b = anneal(&g, &AieArray::default(), 7, 100_000);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.violations, b.violations);
    }

    #[test]
    fn incremental_cost_matches_full_scan() {
        // run a short anneal and verify the tracked violation count via
        // an exact recount of shared-buffer adjacency
        let g = graph(64);
        let array = AieArray::default();
        let r = anneal(&g, &array, 5, 10_000);
        let exact = g
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::SharedBuffer)
            .filter(|e| {
                let (a, b) = (
                    r.placement.coord(e.src).unwrap(),
                    r.placement.coord(e.dst).unwrap(),
                );
                !array.shares_buffer(a, b)
            })
            .count();
        assert_eq!(exact, r.violations);
    }

    fn coords_of(p: &Placement) -> BTreeMap<NodeId, Coord> {
        p.iter().collect()
    }

    #[test]
    fn dense_is_bit_identical_to_legacy() {
        // The in-crate slice of the equivalence corpus (the full sweep is
        // `tests/pnr_equivalence.rs` under `--features legacy-hash-pnr`):
        // identical RNG trace ⇒ identical iterations, violations and
        // final placement, across sizes, seeds and budgets.
        let array = AieArray::default();
        for (cap, budget) in [(16u64, 200_000u64), (64, 20_000), (400, 20_000)] {
            let g = graph(cap);
            for seed in [1u64, 7, 11] {
                let a = anneal(&g, &array, seed, budget);
                let b = legacy::anneal_legacy(&g, &array, seed, budget);
                assert_eq!(a.iterations, b.iterations, "cap {cap} seed {seed}");
                assert_eq!(a.violations, b.violations, "cap {cap} seed {seed}");
                assert_eq!(a.converged, b.converged, "cap {cap} seed {seed}");
                assert_eq!(
                    coords_of(&a.placement),
                    coords_of(&b.placement),
                    "cap {cap} seed {seed}"
                );
            }
        }
    }
}
