//! Constraint-guided placement: the systolic array's regular pattern
//! makes placement a deterministic tiling of replicas onto the grid
//! (paper §III-C-2: "transformation of the kernels' placement into a
//! regular duplicate pattern of a single kernel").

use crate::arch::array::{AieArray, Coord};
use crate::graph::builder::MappedGraph;
use crate::graph::edge::EdgeKind;
use crate::graph::node::NodeId;
use std::collections::HashMap;

/// A placement: physical coordinates for every AIE node.
#[derive(Debug, Clone, Default)]
pub struct Placement {
    pub coords: HashMap<NodeId, Coord>,
}

impl Placement {
    pub fn coord(&self, n: NodeId) -> Option<Coord> {
        self.coords.get(&n).copied()
    }

    /// Column of an AIE node (Algorithm 1's `x_col`).
    pub fn col(&self, n: NodeId) -> Option<u32> {
        self.coord(n).map(|c| c.col)
    }

    /// All placements are within bounds and distinct.
    pub fn is_valid(&self, array: &AieArray) -> bool {
        let mut seen = std::collections::HashSet::new();
        self.coords
            .values()
            .all(|&c| array.contains(c) && seen.insert(c))
    }

    /// Every shared-buffer edge must connect physical neighbours — the
    /// placement constraint that lets ports use the shared buffer.
    pub fn shared_buffers_adjacent(&self, g: &MappedGraph, array: &AieArray) -> bool {
        g.edges
            .iter()
            .filter(|e| e.kind == EdgeKind::SharedBuffer)
            .all(|e| {
                match (self.coord(e.src), self.coord(e.dst)) {
                    (Some(a), Some(b)) => array.shares_buffer(a, b),
                    _ => false,
                }
            })
    }
}

/// Place a mapped graph: replica 0 sits at the origin; further threading
/// replicas tile right-then-up across the grid. Returns None if the
/// replicas do not fit the array.
pub fn place(g: &MappedGraph, array: &AieArray) -> Option<Placement> {
    let (r, c) = g.replica;
    if r > array.rows || c > array.cols {
        return None;
    }
    let per_row = (array.cols / c).max(1); // replicas side by side
    let mut out = Placement::default();
    let mut rep_of_node: HashMap<NodeId, (u32, Coord)> = HashMap::new();
    // Recover each AIE node's replica index and in-replica coordinate
    // from its name (k_r<rep>_<i>_<j>) — stable builder contract.
    for n in g.aie_nodes() {
        let parts: Vec<&str> = n.name.split('_').collect();
        let rep: u32 = parts[1][1..].parse().ok()?;
        let i: u32 = parts[2].parse().ok()?;
        let j: u32 = parts[3].parse().ok()?;
        rep_of_node.insert(n.id, (rep, Coord::new(i, j)));
    }
    for (&id, &(rep, local)) in &rep_of_node {
        let block_row = rep / per_row;
        let block_col = rep % per_row;
        let coord = Coord::new(block_row * r + local.row, block_col * c + local.col);
        if !array.contains(coord) {
            return None;
        }
        out.coords.insert(id, coord);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn graph_for(rec: crate::recurrence::spec::UniformRecurrence, cap: u64) -> MappedGraph {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        build(&cand, &CostModel::new(board))
    }

    #[test]
    fn mm_placement_valid_and_adjacent() {
        let g = graph_for(library::mm(8192, 8192, 8192, DType::F32), 400);
        let array = AieArray::default();
        let p = place(&g, &array).expect("placement");
        assert!(p.is_valid(&array));
        assert!(p.shared_buffers_adjacent(&g, &array));
        assert_eq!(p.coords.len(), 400);
    }

    #[test]
    fn small_graph_placement() {
        let g = graph_for(library::mm(1024, 1024, 1024, DType::F32), 64);
        let array = AieArray::default();
        let p = place(&g, &array).expect("placement");
        assert!(p.is_valid(&array));
        assert!(p.shared_buffers_adjacent(&g, &array));
    }

    #[test]
    fn oversized_replica_rejected() {
        let mut g = graph_for(library::mm(1024, 1024, 1024, DType::F32), 400);
        g.replica = (9, 50); // taller than the array
        assert!(place(&g, &AieArray::default()).is_none());
    }

    #[test]
    fn fir_replicas_tile_the_grid() {
        let g = graph_for(library::fir(1048576, 15, DType::F32), 256);
        let array = AieArray::default();
        let p = place(&g, &array).expect("placement");
        assert!(p.is_valid(&array));
        assert_eq!(p.coords.len(), g.num_aies());
    }
}
