//! Constraint-guided placement: the systolic array's regular pattern
//! makes placement a deterministic tiling of replicas onto the grid
//! (paper §III-C-2: "transformation of the kernels' placement into a
//! regular duplicate pattern of a single kernel").
//!
//! [`Placement`] is stored densely: a coordinate vector indexed by
//! `NodeId` (the builder guarantees node ids are contiguous indices —
//! see [`MappedGraph::node_ids_are_dense`]) mirrored by a flat
//! `row * cols + col` occupancy grid, so the P&R hot path (annealer,
//! congestion model, router, codegen) does array indexing instead of
//! hashing. The two views are kept in lockstep by construction; a
//! property test sweeps random insert sequences asserting they can never
//! disagree.

use crate::arch::array::{AieArray, Coord};
use crate::graph::builder::MappedGraph;
use crate::graph::edge::EdgeKind;
use crate::graph::node::NodeId;

/// A placement: physical coordinates for every AIE node.
///
/// Dense by construction: `coord_of[node]` holds the node's coordinate
/// and `slot_of[row * cols + col]` holds the slot's occupant. Inserting
/// a node onto an occupied slot displaces the previous occupant (its
/// coordinate is cleared), and re-inserting a node vacates its previous
/// slot — the grid and the coordinate vector are exact mirrors at every
/// step, which also makes double-occupancy structurally impossible.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Coordinate per node, indexed by `NodeId`.
    coord_of: Vec<Option<Coord>>,
    /// Occupant per grid slot, keyed `row * cols + col`.
    slot_of: Vec<Option<NodeId>>,
    rows: u32,
    cols: u32,
    placed: usize,
}

impl Default for Placement {
    /// An empty placement on the default VCK5000 grid (8 × 50); the grid
    /// grows automatically if a coordinate beyond it is inserted.
    fn default() -> Self {
        let a = AieArray::default();
        Self::with_grid(a.rows, a.cols)
    }
}

impl Placement {
    /// An empty placement over a `rows` × `cols` grid.
    pub fn with_grid(rows: u32, cols: u32) -> Self {
        Self {
            coord_of: Vec::new(),
            slot_of: vec![None; (rows as usize) * (cols as usize)],
            rows,
            cols,
            placed: 0,
        }
    }

    fn slot_index(&self, c: Coord) -> usize {
        (c.row * self.cols + c.col) as usize
    }

    /// Grow the grid so `c` is addressable (rebuilds the occupancy grid
    /// from the coordinate vector — rare, insert-time only).
    fn ensure_grid(&mut self, c: Coord) {
        if c.row < self.rows && c.col < self.cols {
            return;
        }
        let rows = self.rows.max(c.row + 1);
        let cols = self.cols.max(c.col + 1);
        let mut slot_of = vec![None; (rows as usize) * (cols as usize)];
        for (n, oc) in self.coord_of.iter().enumerate() {
            if let Some(c) = oc {
                slot_of[(c.row * cols + c.col) as usize] = Some(n);
            }
        }
        self.slot_of = slot_of;
        self.rows = rows;
        self.cols = cols;
    }

    /// Place node `n` at `c`. Vacates `n`'s previous slot; displaces any
    /// previous occupant of `c` (its coordinate is cleared).
    pub fn insert(&mut self, n: NodeId, c: Coord) {
        self.ensure_grid(c);
        if self.coord_of.len() <= n {
            self.coord_of.resize(n + 1, None);
        }
        if let Some(old) = self.coord_of[n].take() {
            let i = self.slot_index(old);
            self.slot_of[i] = None;
            self.placed -= 1;
        }
        let i = self.slot_index(c);
        if let Some(prev) = self.slot_of[i].take() {
            self.coord_of[prev] = None;
            self.placed -= 1;
        }
        self.coord_of[n] = Some(c);
        self.slot_of[i] = Some(n);
        self.placed += 1;
    }

    pub fn coord(&self, n: NodeId) -> Option<Coord> {
        self.coord_of.get(n).copied().flatten()
    }

    /// Column of an AIE node (Algorithm 1's `x_col`).
    pub fn col(&self, n: NodeId) -> Option<u32> {
        self.coord(n).map(|c| c.col)
    }

    /// Occupant of grid slot `c`, if any.
    pub fn node_at(&self, c: Coord) -> Option<NodeId> {
        if c.row < self.rows && c.col < self.cols {
            self.slot_of[self.slot_index(c)]
        } else {
            None
        }
    }

    /// Number of placed nodes.
    pub fn len(&self) -> usize {
        self.placed
    }

    pub fn is_empty(&self) -> bool {
        self.placed == 0
    }

    /// Grid dimensions (rows, cols) currently addressable.
    pub fn grid_dims(&self) -> (u32, u32) {
        (self.rows, self.cols)
    }

    /// All placed `(node, coord)` pairs in ascending node order.
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, Coord)> + '_ {
        self.coord_of
            .iter()
            .enumerate()
            .filter_map(|(n, c)| c.map(|c| (n, c)))
    }

    /// Highest occupied column, if anything is placed (sizes the
    /// congestion model's boundary vectors).
    pub fn max_col(&self) -> Option<u32> {
        self.iter().map(|(_, c)| c.col).max()
    }

    /// All placements are within bounds (distinctness is structural: the
    /// occupancy grid cannot hold two nodes on one slot).
    pub fn is_valid(&self, array: &AieArray) -> bool {
        self.iter().all(|(_, c)| array.contains(c))
    }

    /// Every shared-buffer edge must connect physical neighbours — the
    /// placement constraint that lets ports use the shared buffer.
    pub fn shared_buffers_adjacent(&self, g: &MappedGraph, array: &AieArray) -> bool {
        g.edges
            .iter()
            .filter(|e| e.kind == EdgeKind::SharedBuffer)
            .all(|e| {
                match (self.coord(e.src), self.coord(e.dst)) {
                    (Some(a), Some(b)) => array.shares_buffer(a, b),
                    _ => false,
                }
            })
    }
}

/// Place a mapped graph: replica 0 sits at the origin; further threading
/// replicas tile right-then-up across the grid. Returns None if the
/// replicas do not fit the array.
pub fn place(g: &MappedGraph, array: &AieArray) -> Option<Placement> {
    let (r, c) = g.replica;
    if r > array.rows || c > array.cols {
        return None;
    }
    let per_row = (array.cols / c).max(1); // replicas side by side
    let mut out = Placement::with_grid(array.rows, array.cols);
    // Recover each AIE node's replica index and in-replica coordinate
    // from its name (k_r<rep>_<i>_<j>) — stable builder contract.
    for n in g.aie_nodes() {
        let parts: Vec<&str> = n.name.split('_').collect();
        let rep: u32 = parts[1][1..].parse().ok()?;
        let i: u32 = parts[2].parse().ok()?;
        let j: u32 = parts[3].parse().ok()?;
        let block_row = rep / per_row;
        let block_col = rep % per_row;
        let coord = Coord::new(block_row * r + i, block_col * c + j);
        if !array.contains(coord) {
            return None;
        }
        out.insert(n.id, coord);
    }
    // A coordinate collision would have displaced an earlier node (the
    // dense grid cannot double-occupy) — detectable as a count mismatch.
    if out.len() != g.num_aies() {
        return None;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn graph_for(rec: crate::recurrence::spec::UniformRecurrence, cap: u64) -> MappedGraph {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        build(&cand, &CostModel::new(board))
    }

    #[test]
    fn mm_placement_valid_and_adjacent() {
        let g = graph_for(library::mm(8192, 8192, 8192, DType::F32), 400);
        let array = AieArray::default();
        let p = place(&g, &array).expect("placement");
        assert!(p.is_valid(&array));
        assert!(p.shared_buffers_adjacent(&g, &array));
        assert_eq!(p.len(), 400);
    }

    #[test]
    fn small_graph_placement() {
        let g = graph_for(library::mm(1024, 1024, 1024, DType::F32), 64);
        let array = AieArray::default();
        let p = place(&g, &array).expect("placement");
        assert!(p.is_valid(&array));
        assert!(p.shared_buffers_adjacent(&g, &array));
    }

    #[test]
    fn oversized_replica_rejected() {
        let mut g = graph_for(library::mm(1024, 1024, 1024, DType::F32), 400);
        g.replica = (9, 50); // taller than the array
        assert!(place(&g, &AieArray::default()).is_none());
    }

    #[test]
    fn fir_replicas_tile_the_grid() {
        let g = graph_for(library::fir(1048576, 15, DType::F32), 256);
        let array = AieArray::default();
        let p = place(&g, &array).expect("placement");
        assert!(p.is_valid(&array));
        assert_eq!(p.len(), g.num_aies());
    }

    #[test]
    fn grid_mirrors_coords_both_ways() {
        let g = graph_for(library::mm(2048, 2048, 2048, DType::F32), 400);
        let array = AieArray::default();
        let p = place(&g, &array).expect("placement");
        for (n, c) in p.iter() {
            assert_eq!(p.node_at(c), Some(n));
        }
        let occupied = array.coords().filter(|&c| p.node_at(c).is_some()).count();
        assert_eq!(occupied, p.len());
    }

    #[test]
    fn insert_displaces_and_revacates() {
        let mut p = Placement::default();
        p.insert(0, Coord::new(1, 1));
        p.insert(1, Coord::new(2, 2));
        assert_eq!(p.len(), 2);
        // node 1 steals node 0's slot: node 0 is displaced
        p.insert(1, Coord::new(1, 1));
        assert_eq!(p.len(), 1);
        assert_eq!(p.coord(0), None);
        assert_eq!(p.coord(1), Some(Coord::new(1, 1)));
        assert_eq!(p.node_at(Coord::new(2, 2)), None);
        // moving node 1 vacates its old slot
        p.insert(1, Coord::new(3, 3));
        assert_eq!(p.node_at(Coord::new(1, 1)), None);
        assert_eq!(p.node_at(Coord::new(3, 3)), Some(1));
    }

    #[test]
    fn grid_grows_past_default_dims() {
        let mut p = Placement::default();
        p.insert(0, Coord::new(0, 0));
        p.insert(7, Coord::new(9, 60)); // beyond the 8×50 default
        assert_eq!(p.grid_dims(), (10, 61));
        assert_eq!(p.node_at(Coord::new(0, 0)), Some(0));
        assert_eq!(p.node_at(Coord::new(9, 60)), Some(7));
        assert_eq!(p.max_col(), Some(60));
    }
}
