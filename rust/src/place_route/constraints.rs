//! Constraint rendering: the location + routing constraints WideSA hands
//! the AIE compiler (the JSON the real flow passes via `aie.constraints`
//! files). Produced from the deterministic placement and the PLIO
//! assignment; consumed by codegen and by the compile experiment (E5).

use crate::graph::builder::MappedGraph;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use std::collections::HashMap;
use std::fmt::Write;

/// The constraint set for one design.
#[derive(Debug, Clone, Default)]
pub struct ConstraintSet {
    /// kernel instance name → (row, col)
    pub kernel_locations: Vec<(String, u32, u32)>,
    /// PLIO port name → interface column
    pub plio_columns: Vec<(String, u32)>,
    /// shared-buffer edges (src kernel, dst kernel) fixed to adjacency
    pub buffer_bindings: Vec<(String, String)>,
}

impl ConstraintSet {
    pub fn from_design(
        g: &MappedGraph,
        placement: &Placement,
        plio_cols: &HashMap<NodeId, u32>,
    ) -> Self {
        let mut out = ConstraintSet::default();
        for n in g.aie_nodes() {
            if let Some(c) = placement.coord(n.id) {
                out.kernel_locations.push((n.name.clone(), c.row, c.col));
            }
        }
        for n in g.plio_nodes() {
            if let Some(&col) = plio_cols.get(&n.id) {
                out.plio_columns.push((n.name.clone(), col));
            }
        }
        for e in &g.edges {
            if e.kind == crate::graph::edge::EdgeKind::SharedBuffer {
                out.buffer_bindings
                    .push((g.nodes[e.src].name.clone(), g.nodes[e.dst].name.clone()));
            }
        }
        out.kernel_locations.sort();
        out.plio_columns.sort();
        out.buffer_bindings.sort();
        out
    }

    /// Render as the aiecompiler-style JSON constraint file.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"NodeConstraints\": {\n");
        let mut first = true;
        for (name, row, col) in &self.kernel_locations {
            if !first {
                s.push_str(",\n");
            }
            write!(
                s,
                "    \"{name}\": {{ \"tileLocation\": {{ \"row\": {row}, \"column\": {col} }} }}"
            )
            .unwrap();
            first = false;
        }
        for (name, col) in &self.plio_columns {
            if !first {
                s.push_str(",\n");
            }
            write!(s, "    \"{name}\": {{ \"shimColumn\": {col} }}").unwrap();
            first = false;
        }
        s.push_str("\n  }\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::array::AieArray;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::graph::packet::merge_ports;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::place_route::placement::place;
    use crate::plio::assignment::assign;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn set_for(cap: u64) -> ConstraintSet {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(8192, 8192, 8192, DType::F32), &board, &cons).unwrap();
        let model = CostModel::new(board.clone());
        let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
        let pl = place(&g, &AieArray::default()).unwrap();
        let a = assign(&g, &pl, &board.plio, 6, 6);
        ConstraintSet::from_design(&g, &pl, &a.columns)
    }

    #[test]
    fn constraints_cover_all_kernels_and_ports() {
        let s = set_for(400);
        assert_eq!(s.kernel_locations.len(), 400);
        assert!(!s.plio_columns.is_empty());
        assert!(!s.buffer_bindings.is_empty());
    }

    #[test]
    fn json_renders_parseable_structure() {
        let s = set_for(100);
        let j = s.to_json();
        assert!(j.starts_with('{'));
        assert!(j.contains("tileLocation"));
        assert!(j.contains("shimColumn"));
        // crude balance check
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn deterministic_output() {
        assert_eq!(set_for(100).to_json(), set_for(100).to_json());
    }
}
