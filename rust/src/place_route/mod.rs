//! Placement & routing substrate — the stand-in for the Vitis AIE
//! compiler's ILP place-and-route (paper §II-A-2, §III-C).
//!
//! [`placement`] realises the systolic regular-duplicate placement with
//! shared-buffer constraints; [`router`] routes every stream with XY mesh
//! routing under per-boundary channel capacities; [`constraints`] renders
//! the location constraints WideSA hands the compiler; [`anneal`] is the
//! unconstrained baseline (simulated annealing standing in for the raw
//! ILP flow); [`compiler`] wraps both into the compile-success/compile-
//! time experiment (E5).
//!
//! Paper map: [`placement::place`] ↔ §III-C-2's "regular duplicate
//! pattern of a single kernel" (deterministic systolic placement);
//! [`router::route_all`] ↔ XY mesh routing under the per-boundary
//! `RC_west`/`RC_east` channel budgets; [`constraints::ConstraintSet`] ↔
//! the location-constraint file WideSA hands `aiecompiler`;
//! [`anneal::anneal`] ↔ the unconstrained solver whose degradation at
//! scale motivates §II-A-2.
//!
//! **Hot path layout:** the whole post-ranking compile pipeline is
//! dense-indexed. Node ids are contiguous vector indices (the builder
//! contract, [`crate::graph::builder::MappedGraph::node_ids_are_dense`]),
//! so [`placement::Placement`] is a flat coordinate vector mirrored by a
//! `row * cols + col` occupancy grid, the annealer keeps edge incidence
//! in a CSR and its violated edges in a bitset worklist, and the per-pair
//! / per-column tallies in [`router`] and [`crate::plio`] are flat
//! vectors. No `HashMap` is touched between ranking and codegen. The
//! pre-dense annealer survives as `anneal::legacy` (feature
//! `legacy-hash-pnr` or tests) purely as the bit-identity oracle and the
//! baseline for `bench_compile`'s ≥2× throughput gate (`make pnr-smoke`).

pub mod anneal;
pub mod compiler;
pub mod constraints;
pub mod placement;
pub mod router;

pub use compiler::{compile, CompileOutcome};
pub use placement::{place, Placement};
pub use router::{route_all, RoutingReport};
