//! Placement & routing substrate — the stand-in for the Vitis AIE
//! compiler's ILP place-and-route (paper §II-A-2, §III-C).
//!
//! [`placement`] realises the systolic regular-duplicate placement with
//! shared-buffer constraints; [`router`] routes every stream with XY mesh
//! routing under per-boundary channel capacities; [`constraints`] renders
//! the location constraints WideSA hands the compiler; [`anneal`] is the
//! unconstrained baseline (simulated annealing standing in for the raw
//! ILP flow); [`compiler`] wraps both into the compile-success/compile-
//! time experiment (E5).
//!
//! Paper map: [`placement::place`] ↔ §III-C-2's "regular duplicate
//! pattern of a single kernel" (deterministic systolic placement);
//! [`router::route_all`] ↔ XY mesh routing under the per-boundary
//! `RC_west`/`RC_east` channel budgets; [`constraints::ConstraintSet`] ↔
//! the location-constraint file WideSA hands `aiecompiler`;
//! [`anneal::anneal`] ↔ the unconstrained solver whose degradation at
//! scale motivates §II-A-2.

pub mod anneal;
pub mod compiler;
pub mod constraints;
pub mod placement;
pub mod router;

pub use compiler::{compile, CompileOutcome};
pub use placement::{place, Placement};
pub use router::{route_all, RoutingReport};
