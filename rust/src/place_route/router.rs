//! Mesh stream router: XY routes for every PLIO↔AIE stream under
//! per-boundary channel capacities — the routing half of the Vitis
//! stand-in. Inter-core shared-buffer edges need no NoC resources (that
//! is exactly why the systolic placement constraints help the compiler).
//!
//! Per-pair deduplication and broadcast trunk extents use the same dense
//! `NodeId`-indexed structures as the congestion model
//! ([`crate::plio::congestion::PlioPairSet`],
//! [`crate::plio::congestion::BcastExtents`]) — shared helpers, so the
//! router and the analytic model cannot disagree on pair identity or
//! trunk shape.

use crate::arch::array::Coord;
use crate::arch::noc::{ChannelOccupancy, StreamRoute};
use crate::graph::builder::MappedGraph;
use crate::graph::edge::EdgeKind;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use crate::plio::congestion::{BcastExtents, PlioPairSet};
use std::collections::HashMap;

/// Routing outcome for a placed+assigned design.
#[derive(Debug, Clone)]
pub struct RoutingReport {
    /// One route per stream edge (keyed by edge index).
    pub routes: Vec<(usize, StreamRoute)>,
    pub occupancy: ChannelOccupancy,
    pub max_west: u32,
    pub max_east: u32,
    pub total_hops: usize,
    pub success: bool,
}

/// Route all stream edges. PLIO endpoints sit at row 0 of their assigned
/// column; packet-switched siblings share their port's route budget (the
/// congestion model already deduplicates per (port, AIE) pair — here each
/// distinct (port, AIE) stream is routed).
pub fn route_all(
    g: &MappedGraph,
    placement: &Placement,
    plio_cols: &HashMap<NodeId, u32>,
    cols: u32,
    rc_west: u32,
    rc_east: u32,
) -> RoutingReport {
    let mut occ = ChannelOccupancy::new(cols);
    let mut routes = Vec::new();
    let mut total_hops = 0usize;
    let mut seen = PlioPairSet::new(g);
    // Broadcast multicast: route the horizontal trunk once per port (to
    // the extreme columns), not per destination.
    let mut bcast = BcastExtents::new(g.nodes.len());
    let endpoint = |n: NodeId| -> Option<Coord> {
        if g.nodes[n].is_aie() {
            placement.coord(n)
        } else {
            plio_cols.get(&n).map(|&c| Coord::new(0, c))
        }
    };
    for (idx, e) in g.edges.iter().enumerate() {
        if e.kind == EdgeKind::SharedBuffer {
            continue; // neighbour DMA, no NoC
        }
        let (Some(from), Some(to)) = (endpoint(e.src), endpoint(e.dst)) else {
            continue;
        };
        if e.kind == EdgeKind::Broadcast {
            bcast.note(e.src, to.col);
            continue;
        }
        if !seen.insert_directed(e.src, e.dst) {
            continue; // packet-switched duplicates share the port route
        }
        let route = StreamRoute::xy(from, to);
        total_hops += route.len();
        occ.add_route(&route);
        routes.push((idx, route));
    }
    for (p, (lo, hi)) in bcast.iter() {
        if let Some(from) = endpoint(p) {
            for target in [lo, hi] {
                if target != from.col {
                    let route = StreamRoute::xy(from, Coord::new(0, target));
                    total_hops += route.len();
                    occ.add_route(&route);
                }
            }
        }
    }
    let (mw, me) = (occ.max_west(), occ.max_east());
    RoutingReport {
        routes,
        max_west: mw,
        max_east: me,
        occupancy: occ,
        total_hops,
        success: mw <= rc_west && me <= rc_east,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::array::AieArray;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::graph::packet::merge_ports;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::place_route::placement::place;
    use crate::plio::assignment::assign;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn routed(rec: crate::recurrence::spec::UniformRecurrence, cap: u64) -> RoutingReport {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board.clone());
        let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
        let pl = place(&g, &AieArray::default()).unwrap();
        let a = assign(&g, &pl, &board.plio, board.array.rc_west, board.array.rc_east);
        route_all(
            &g,
            &pl,
            &a.columns,
            board.array.cols,
            board.array.rc_west,
            board.array.rc_east,
        )
    }

    #[test]
    fn mm_routes_successfully() {
        let r = routed(library::mm(8192, 8192, 8192, DType::F32), 400);
        assert!(r.success, "W {} E {}", r.max_west, r.max_east);
        assert!(!r.routes.is_empty());
    }

    #[test]
    fn conv_routes_successfully() {
        let r = routed(library::conv2d(10240, 10240, 8, 8, DType::I8), 400);
        assert!(r.success, "W {} E {}", r.max_west, r.max_east);
    }

    #[test]
    fn congestion_matches_router_occupancy() {
        // The analytic congestion model and the router must agree on
        // horizontal crossings (routes are XY with horizontal at row 0).
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(8192, 8192, 8192, DType::F32), &board, &cons).unwrap();
        let model = CostModel::new(board.clone());
        let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
        let pl = place(&g, &AieArray::default()).unwrap();
        let a = assign(&g, &pl, &board.plio, 6, 6);
        let rep = route_all(&g, &pl, &a.columns, 50, 6, 6);
        assert_eq!(rep.max_west, a.congestion.max_west());
        assert_eq!(rep.max_east, a.congestion.max_east());
    }

    #[test]
    fn hops_are_reasonable() {
        let r = routed(library::fir(1048576, 15, DType::F32), 256);
        // every route is at most array diameter long
        for (_, route) in &r.routes {
            assert!(route.len() <= (50 + 8) as usize);
        }
    }
}
