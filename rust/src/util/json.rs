//! Minimal JSON parser and writer (objects, arrays, strings, numbers,
//! booleans, null) — enough to read `artifacts/manifest.json` and to
//! speak the `serve` subsystem's JSON-lines protocol without a serde
//! dependency (the offline vendor set has none).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Build an object from key/value pairs (keys sort alphabetically —
    /// `BTreeMap` — so rendered output is canonical).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Integer-valued number. Exact for `|n| < 2⁵³` (the f64 mantissa);
    /// full-width 64-bit hashes must travel as 16-hex strings instead
    /// (the convention `design_key` responses and snapshots use).
    pub fn num_u64(n: u64) -> Json {
        Json::Num(n as f64)
    }

    /// Integer-valued number (same 2⁵³ caveat as [`Json::num_u64`]).
    pub fn num_i64(n: i64) -> Json {
        Json::Num(n as f64)
    }

    /// Integer-valued number (same 2⁵³ caveat as [`Json::num_u64`]).
    pub fn num_usize(n: usize) -> Json {
        Json::Num(n as f64)
    }
}

/// Render with JSON string escaping.
fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Compact (single-line) rendering; `parse(v.to_string())` round-trips.
/// Non-finite numbers (which JSON cannot represent) render as `null`.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) if !n.is_finite() => write!(f, "null"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(s: &str) -> Result<Json, ParseError> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    let v = value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(err(pos, "trailing data"));
    }
    Ok(v)
}

fn err(pos: usize, msg: &str) -> ParseError {
    ParseError {
        pos,
        msg: msg.into(),
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn value(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => obj(b, pos),
        Some(b'[') => arr(b, pos),
        Some(b'"') => Ok(Json::Str(string(b, pos)?)),
        Some(b't') => lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => lit(b, pos, "null", Json::Null),
        Some(c) if c.is_ascii_digit() || *c == b'-' => num(b, pos),
        _ => Err(err(*pos, "expected value")),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, ParseError> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(err(*pos, "bad literal"))
    }
}

fn num(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| err(start, "bad number"))
}

fn string(b: &[u8], pos: &mut usize) -> Result<String, ParseError> {
    if b.get(*pos) != Some(&b'"') {
        return Err(err(*pos, "expected string"));
    }
    *pos += 1;
    let mut out = String::new();
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| err(*pos, "bad \\u"))?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|_| err(*pos, "bad \\u"))?;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => return Err(err(*pos, "bad escape")),
                }
                *pos += 1;
            }
            _ => {
                // copy UTF-8 bytes through
                let ch_len = utf8_len(c);
                out.push_str(std::str::from_utf8(&b[*pos..*pos + ch_len]).map_err(|_| err(*pos, "bad utf8"))?);
                *pos += ch_len;
            }
        }
    }
    Err(err(*pos, "unterminated string"))
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn arr(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // [
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(err(*pos, "expected , or ]")),
        }
    }
}

fn obj(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // {
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let k = string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(err(*pos, "expected :"));
        }
        *pos += 1;
        let v = value(b, pos)?;
        out.insert(k, v);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(err(*pos, "expected , or }")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let s = r#"{
            "mm_f32_128": {
                "hlo": "mm_f32_128.hlo.txt",
                "inputs": [{"shape": [128, 128], "dtype": "float32"}],
                "outputs": [{"shape": [128, 128], "dtype": "float32"}]
            }
        }"#;
        let v = parse(s).unwrap();
        let entry = v.get("mm_f32_128").unwrap();
        assert_eq!(entry.get("hlo").unwrap().as_str(), Some("mm_f32_128.hlo.txt"));
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_u64(), Some(128));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_arrays() {
        let v = parse("[1, [2, 3], []]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_arr().unwrap().len(), 2);
        assert!(a[2].as_arr().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn render_round_trips() {
        let v = Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("n", Json::Num(1.5)),
            ("id", Json::Num(7.0)),
            ("msg", Json::Str("a\"b\\c\nd".into())),
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Null])),
        ]);
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
        // integral floats render without a decimal point
        assert!(s.contains("\"id\":7"));
        assert!(s.contains("\"n\":1.5"));
    }

    #[test]
    fn render_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn typed_constructors_and_accessors() {
        assert_eq!(Json::str("x"), Json::Str("x".into()));
        assert_eq!(Json::num_u64(7), Json::Num(7.0));
        assert_eq!(Json::num_i64(-3), Json::Num(-3.0));
        assert_eq!(Json::num_usize(12), Json::Num(12.0));
        assert_eq!(Json::Num(-3.0).as_i64(), Some(-3));
        assert_eq!(Json::Num(12.0).as_usize(), Some(12));
        assert_eq!(Json::Null.as_i64(), None);
        // f64 round-trips its shortest decimal rendering exactly, which
        // is what snapshot bit-identity relies on
        for x in [0.1, 1.0 / 3.0, 1e300, -2.5e-7, f64::MIN_POSITIVE] {
            let s = Json::Num(x).to_string();
            assert_eq!(parse(&s).unwrap().as_f64().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn render_escapes_control_chars() {
        let s = Json::Str("\u{1}tab\there".into()).to_string();
        assert_eq!(s, "\"\\u0001tab\\there\"");
        assert_eq!(parse(&s).unwrap().as_str(), Some("\u{1}tab\there"));
    }
}
