//! Dense fixed-capacity bitset over `u64` words.
//!
//! The membership structure behind the annealer's violated-edge worklist
//! ([`crate::place_route::anneal`]) and the per-pair stream deduplication
//! in the congestion model and router: O(1) set/clear/test, and a
//! word-skipping circular "first set bit at or after" query that replaces
//! an O(n) element-by-element scan with an O(n/64) word scan (with early
//! exit on the first non-zero word).

/// A fixed-capacity set of `usize` indices in `[0, len)`.
#[derive(Debug, Clone)]
pub struct DenseBitSet {
    words: Vec<u64>,
    len: usize,
    count: usize,
}

impl DenseBitSet {
    /// An empty set with capacity for indices `0..len`.
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
            count: 0,
        }
    }

    /// Capacity (number of addressable indices).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no index is set.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Number of indices currently set.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Membership test.
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    /// Set or clear index `i`; returns the previous membership.
    pub fn set(&mut self, i: usize, v: bool) -> bool {
        debug_assert!(i < self.len);
        let (w, m) = (i / 64, 1u64 << (i % 64));
        let was = self.words[w] & m != 0;
        if v {
            self.words[w] |= m;
            if !was {
                self.count += 1;
            }
        } else {
            self.words[w] &= !m;
            if was {
                self.count -= 1;
            }
        }
        was
    }

    /// Insert index `i`; returns true when it was newly inserted (the
    /// `HashSet::insert` contract, for deduplication loops).
    pub fn insert(&mut self, i: usize) -> bool {
        !self.set(i, true)
    }

    /// The first set index at or after `start`, wrapping circularly past
    /// the end — exactly the element an element-by-element scan
    /// `(start + k) % len` for `k = 0..len` would find first. `None` when
    /// the set is empty.
    pub fn first_set_circular(&self, start: usize) -> Option<usize> {
        if self.count == 0 {
            return None;
        }
        debug_assert!(start < self.len);
        let nw = self.words.len();
        let (sw, sb) = (start / 64, start % 64);
        // partial first word: bits >= start
        let w = self.words[sw] & (!0u64 << sb);
        if w != 0 {
            return Some(sw * 64 + w.trailing_zeros() as usize);
        }
        for k in 1..=nw {
            let i = (sw + k) % nw;
            let mut w = self.words[i];
            if i == sw {
                // wrapped all the way around: only bits < start remain
                w &= (1u64 << sb) - 1;
            }
            if w != 0 {
                return Some(i * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count() {
        let mut s = DenseBitSet::new(200);
        assert!(s.is_empty());
        assert!(!s.set(3, true));
        assert!(s.set(3, true)); // already present
        assert!(s.insert(130));
        assert!(!s.insert(130));
        assert_eq!(s.count(), 2);
        assert!(s.get(3) && s.get(130));
        assert!(s.set(3, false));
        assert!(!s.set(3, false));
        assert_eq!(s.count(), 1);
        assert!(!s.get(3));
    }

    #[test]
    fn circular_first_matches_linear_scan() {
        // sweep random memberships and starts against the reference scan
        let mut rng = crate::util::rng::XorShift64::new(42);
        for _ in 0..200 {
            let len = 1 + rng.gen_range(300) as usize;
            let mut s = DenseBitSet::new(len);
            let mut member = vec![false; len];
            for _ in 0..rng.gen_range(64) {
                let i = rng.gen_range(len as u64) as usize;
                let v = rng.gen_range(2) == 0;
                s.set(i, v);
                member[i] = v;
            }
            for _ in 0..8 {
                let start = rng.gen_range(len as u64) as usize;
                let reference = (0..len).map(|k| (start + k) % len).find(|&i| member[i]);
                assert_eq!(s.first_set_circular(start), reference, "len {len} start {start}");
            }
        }
    }

    #[test]
    fn circular_first_empty_and_exact_boundaries() {
        let mut s = DenseBitSet::new(128);
        assert_eq!(s.first_set_circular(0), None);
        s.set(0, true);
        assert_eq!(s.first_set_circular(0), Some(0));
        assert_eq!(s.first_set_circular(1), Some(0)); // wraps
        assert_eq!(s.first_set_circular(127), Some(0));
        s.set(127, true);
        assert_eq!(s.first_set_circular(1), Some(127));
        assert_eq!(s.first_set_circular(127), Some(127));
    }
}
