//! Deterministic xorshift64* PRNG — reproducible workloads and annealing
//! without an external dependency.

#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed.max(1),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform float in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard-normal-ish via sum of uniforms (Irwin–Hall, k=12).
    pub fn gen_normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.gen_f64();
        }
        s - 6.0
    }

    /// Fill a buffer with small random f32 values.
    pub fn fill_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = (self.gen_f64() as f32) * 2.0 - 1.0;
        }
    }

    /// Fill a buffer with small random i32 values in [-8, 8).
    pub fn fill_i32(&mut self, buf: &mut [i32]) {
        for v in buf.iter_mut() {
            *v = self.gen_range(16) as i32 - 8;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_respected() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            assert!(r.gen_range(10) < 10);
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = XorShift64::new(3);
        let n = 10_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.gen_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
