//! Minimal benchmarking harness (the offline vendor set has no criterion):
//! warmup + N timed runs, reporting min/median/mean. `cargo bench` runs
//! the `rust/benches/*.rs` binaries built on this.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let scale = |s: f64| -> String {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else if s >= 1e-6 {
                format!("{:.3} µs", s * 1e6)
            } else {
                format!("{:.1} ns", s * 1e9)
            }
        };
        format!(
            "{:48} min {:>12} median {:>12} mean {:>12} ({} iters)",
            self.name,
            scale(self.min_s),
            scale(self.median_s),
            scale(self.mean_s),
            self.iters
        )
    }
}

/// Time `f` over `iters` runs after one warmup; prints and returns stats.
pub fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) -> BenchResult {
    f(); // warmup
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let res = BenchResult {
        name: name.to_string(),
        iters,
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
    };
    println!("{}", res.report());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_stats() {
        let mut x = 0u64;
        let r = bench("noop-ish", 5, || {
            x = x.wrapping_add(std::hint::black_box(17));
        });
        assert!(r.min_s <= r.median_s);
        assert!(r.min_s <= r.mean_s);
        assert_eq!(r.iters, 5);
        assert!(r.report().contains("noop-ish"));
    }
}
