//! Plain-text table rendering for the evaluation harness — the CLI prints
//! the same rows the paper's tables report.

#[derive(Debug, Default, Clone)]
pub struct TextTable {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            ..Default::default()
        }
    }

    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        self.rows.push(cols);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |row: &[String]| -> String {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", cells.join(" | "))
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
            out.push_str(&format!("|-{}-|", sep.join("-|-")));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format TOPS-style numbers the way the paper prints them.
pub fn fmt3(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.1}")
    } else if x >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = TextTable::new("T");
        t.header(&["name", "tops"]);
        t.row(vec!["mm".into(), "4.15".into()]);
        t.row(vec!["conv2d".into(), "36.02".into()]);
        let s = t.render();
        assert!(s.contains("## T"));
        assert!(s.contains("| mm     | 4.15  |"));
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    fn fmt3_scales_precision() {
        assert_eq!(fmt3(4.153), "4.153");
        assert_eq!(fmt3(32.49), "32.49");
        assert_eq!(fmt3(128.0), "128.0");
    }
}
