//! Stable 64-bit hashing (FNV-1a) for canonical keys.
//!
//! `std::hash::DefaultHasher` makes no cross-version (or cross-process,
//! with randomized state) stability promise, but the serve layer's design
//! cache keys are part of the wire protocol — a client that remembers a
//! key must get the same design back from a restarted server. FNV-1a is
//! tiny, allocation-free and bit-for-bit reproducible everywhere.

/// FNV-1a 64-bit incremental hasher.
///
/// ```
/// use widesa::util::hash::Fnv64;
///
/// let mut h = Fnv64::new();
/// h.write_str("mm");
/// h.write_u64(8192);
/// let a = h.finish();
/// // Same inputs, same key — across runs and machines.
/// let mut h2 = Fnv64::new();
/// h2.write_str("mm");
/// h2.write_u64(8192);
/// assert_eq!(a, h2.finish());
/// ```
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Self(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u8(&mut self, v: u8) {
        self.write(&[v]);
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// Hash an `f64` by its bit pattern (exact, no epsilon games — two
    /// configs are "the same" only if their floats are identical).
    pub fn write_f64(&mut self, v: f64) {
        self.write(&v.to_bits().to_le_bytes());
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_u8(v as u8);
    }

    /// Length-prefixed so `("ab","c")` and `("a","bc")` hash differently.
    pub fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        let mut h = Fnv64::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn order_and_boundaries_matter() {
        let mut ab_c = Fnv64::new();
        ab_c.write_str("ab");
        ab_c.write_str("c");
        let mut a_bc = Fnv64::new();
        a_bc.write_str("a");
        a_bc.write_str("bc");
        assert_ne!(ab_c.finish(), a_bc.finish());
    }

    #[test]
    fn floats_hash_by_bits() {
        let mut a = Fnv64::new();
        a.write_f64(0.1 + 0.2);
        let mut b = Fnv64::new();
        b.write_f64(0.3);
        // 0.1+0.2 != 0.3 in f64 — distinct bit patterns, distinct keys.
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn empty_is_offset_basis() {
        assert_eq!(Fnv64::new().finish(), 0xcbf2_9ce4_8422_2325);
    }
}
