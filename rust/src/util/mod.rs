//! Small shared utilities: deterministic PRNG, integer math, formatting.

pub mod bench;
pub mod json;
pub mod math;
pub mod rng;
pub mod table;

pub use math::{ceil_div, factor_pairs, gcd, lcm};
pub use rng::XorShift64;
pub use table::TextTable;
