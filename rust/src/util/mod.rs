//! Small shared utilities: deterministic PRNG, integer math, formatting,
//! stable hashing, a dense bitset and a dependency-free JSON
//! reader/writer.

pub mod bench;
pub mod bitset;
pub mod hash;
pub mod json;
pub mod math;
pub mod rng;
pub mod table;

pub use math::{ceil_div, factor_pairs, gcd, lcm};
pub use rng::XorShift64;
pub use table::TextTable;
