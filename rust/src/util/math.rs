//! Integer helpers used across tiling, partitioning and the cost model.

/// Ceiling division for positive integers.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Greatest common divisor (Euclid).
pub fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Least common multiple.
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        0
    } else {
        a / gcd(a, b) * b
    }
}

/// All ordered factor pairs (r, c) with r·c == n.
pub fn factor_pairs(n: u64) -> Vec<(u64, u64)> {
    let mut out = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            out.push((d, n / d));
            if d != n / d {
                out.push((n / d, d));
            }
        }
        d += 1;
    }
    out.sort_unstable();
    out
}

/// Divisors of n in ascending order.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            out.push(d);
            if d != n / d {
                out.push(n / d);
            }
        }
        d += 1;
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_rounds_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 100), 1);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn gcd_lcm_basic() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(0, 5), 0);
    }

    #[test]
    fn factor_pairs_cover_all() {
        let ps = factor_pairs(12);
        assert!(ps.contains(&(3, 4)));
        assert!(ps.contains(&(12, 1)));
        for (r, c) in ps {
            assert_eq!(r * c, 12);
        }
    }

    #[test]
    fn divisors_sorted_complete() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(13), vec![1, 13]);
    }
}
