//! RAII span timers and Chrome trace-event export.
//!
//! A [`Span`] measures a wall-clock interval and, when tracing is
//! enabled, records a completed event into a bounded per-thread buffer.
//! The buffer flushes into the process-wide sink whenever the thread's
//! *outermost* span closes (and on thread exit), so flushing never
//! interleaves with hot work. The sink is bounded too: past
//! [`MAX_SINK_EVENTS`] new events are counted as dropped rather than
//! growing without bound.
//!
//! **Trace IDs.** Every serve request gets an ID from
//! [`next_trace_id`]; [`TraceCtx::set`] installs it for the current
//! thread (restoring the previous one on drop), and worker-pool jobs
//! capture [`current_trace`] at submission and re-install it inside the
//! closure — that is the whole cross-thread propagation story, and it's
//! what lets Perfetto's flows / the `obs-check` validator group one
//! request's spans across the DSE pool.
//!
//! **Off by default.** [`enabled`] is a relaxed atomic load; a disabled
//! span takes two `Instant::now` calls and touches nothing shared. The
//! duration is still measured because callers like
//! `place_route::compiler` derive `StageTimings` from [`Span::end_ms`]
//! whether or not anyone is exporting traces.

use crate::util::json::Json;
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Sink capacity; beyond it events are dropped (and counted).
pub const MAX_SINK_EVENTS: usize = 1 << 18;

/// Per-thread buffer flush threshold (also flushed whenever the
/// outermost span on the thread closes).
const THREAD_BUF_FLUSH: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);

fn sink() -> &'static Mutex<Vec<TraceEvent>> {
    static SINK: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

/// Process start reference for trace timestamps (µs since first use).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Is span *recording* on? (Spans still measure time when off.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on/off process-wide.
pub fn set_enabled(on: bool) {
    epoch(); // pin the timestamp origin before the first event
    ENABLED.store(on, Ordering::Relaxed);
}

/// Allocate a fresh request-scoped trace ID (never 0; 0 means "none").
pub fn next_trace_id() -> u64 {
    NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed)
}

/// Events dropped because the sink was full.
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// One completed span, Chrome trace-event "X" (complete) phase.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Span name, e.g. `"pnr.place"`.
    pub name: &'static str,
    /// Category, e.g. `"pnr"` — Perfetto groups/filters by this.
    pub cat: &'static str,
    /// Start, µs since process trace epoch.
    pub ts_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    /// Stable small integer per OS thread (assigned on first span).
    pub tid: u64,
    /// Request correlation ID (0 = outside any request).
    pub trace_id: u64,
}

struct ThreadBuf {
    events: Vec<TraceEvent>,
    depth: usize,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let mut sink = sink().lock().unwrap();
        let room = MAX_SINK_EVENTS.saturating_sub(sink.len());
        if room >= self.events.len() {
            sink.append(&mut self.events);
        } else {
            DROPPED.fetch_add((self.events.len() - room) as u64, Ordering::Relaxed);
            sink.extend(self.events.drain(..).take(room));
            self.events.clear();
        }
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf { events: Vec::new(), depth: 0 });
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
    static TID: Cell<u64> = const { Cell::new(0) };
}

fn thread_tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

/// The trace ID installed on this thread (0 if none).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(Cell::get)
}

/// Guard installing a trace ID for the current thread; restores the
/// previous ID on drop, so nested requests (tests, batch fan-out on the
/// caller thread) unwind correctly.
pub struct TraceCtx {
    prev: u64,
}

impl TraceCtx {
    pub fn set(trace_id: u64) -> TraceCtx {
        let prev = CURRENT_TRACE.with(|c| c.replace(trace_id));
        TraceCtx { prev }
    }
}

impl Drop for TraceCtx {
    fn drop(&mut self) {
        CURRENT_TRACE.with(|c| c.set(self.prev));
    }
}

/// RAII span: measures from [`Span::begin`] until [`Span::end_ms`] or
/// drop. When recording is enabled the completed interval lands in the
/// per-thread buffer tagged with the thread's current trace ID.
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start: Instant,
    ts_us: u64,
    /// Captured at begin so a mid-span `set_enabled` flip can't record
    /// an end without a begin-side depth increment.
    recording: bool,
    finished: bool,
}

impl Span {
    pub fn begin(name: &'static str, cat: &'static str) -> Span {
        let recording = enabled();
        let start = Instant::now();
        let ts_us = if recording {
            BUF.with(|b| b.borrow_mut().depth += 1);
            start.duration_since(epoch()).as_micros() as u64
        } else {
            0
        };
        Span { name, cat, start, ts_us, recording, finished: false }
    }

    /// Elapsed so far, in milliseconds, without ending the span.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// End the span and return its measured duration in milliseconds
    /// (the value `StageTimings` stores — one measurement, two uses).
    pub fn end_ms(mut self) -> f64 {
        self.finish()
    }

    fn finish(&mut self) -> f64 {
        debug_assert!(!self.finished);
        self.finished = true;
        let dur = self.start.elapsed();
        if self.recording {
            let ev = TraceEvent {
                name: self.name,
                cat: self.cat,
                ts_us: self.ts_us,
                dur_us: dur.as_micros() as u64,
                tid: thread_tid(),
                trace_id: current_trace(),
            };
            BUF.with(|b| {
                let mut b = b.borrow_mut();
                b.events.push(ev);
                b.depth -= 1;
                if b.depth == 0 || b.events.len() >= THREAD_BUF_FLUSH {
                    b.flush();
                }
            });
        }
        dur.as_secs_f64() * 1e3
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.finished {
            self.finish();
        }
    }
}

/// Non-draining copy of the sink (tests filter by their own trace ID so
/// concurrent tests can't disturb each other).
pub fn snapshot_events() -> Vec<TraceEvent> {
    sink().lock().unwrap().clone()
}

/// Drain the sink (CLI export path).
pub fn drain_events() -> Vec<TraceEvent> {
    std::mem::take(&mut *sink().lock().unwrap())
}

/// Render events as Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` object form), loadable in `chrome://tracing`
/// and <https://ui.perfetto.dev>. Events are sorted by (tid, ts) so the
/// output is stable for a given event set.
pub fn export_chrome(events: &[TraceEvent]) -> Json {
    let mut evs: Vec<&TraceEvent> = events.iter().collect();
    evs.sort_by_key(|e| (e.tid, e.ts_us, std::cmp::Reverse(e.dur_us)));
    let arr = evs
        .into_iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::Str(e.name.to_string())),
                ("cat", Json::Str(e.cat.to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::num_u64(e.ts_us)),
                ("dur", Json::num_u64(e.dur_us)),
                ("pid", Json::num_u64(1)),
                ("tid", Json::num_u64(e.tid)),
                ("args", Json::obj(vec![("trace_id", Json::num_u64(e.trace_id))])),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Validation report from [`validate_chrome`].
#[derive(Debug)]
pub struct ChromeReport {
    /// Total events in the document.
    pub events: usize,
    /// Name of the root (longest) span.
    pub root_name: String,
    /// Root span duration, µs.
    pub root_dur_us: u64,
    /// Fraction of the root span's duration accounted for by its direct
    /// children on the root's thread (the "≥95 % of wall is attributed"
    /// acceptance number).
    pub root_coverage: f64,
    /// Distinct non-zero trace IDs in the document.
    pub trace_ids: usize,
}

/// Truncation slack: `ts` and `dur` are independently truncated to whole
/// µs, so a child's recorded end may exceed its parent's by up to 2 µs.
const NEST_SLACK_US: u64 = 2;

/// Validate a Chrome trace-event document (as produced by
/// [`export_chrome`] and written by `--trace-out`): every event is a
/// well-formed `"X"` phase with a `trace_id`, spans on each thread
/// strictly nest (within [`NEST_SLACK_US`]), the pipeline hierarchies
/// hold (`pnr.place`/`pnr.assign`/`pnr.route` inside a same-thread
/// `pnr`; `dse.plan`/`dse.score`/`dse.rank` inside a same-trace-ID `dse`
/// interval, which crosses threads via the worker pools;
/// `dse.rank.sort`/`dse.rank.frontier` inside `dse.rank`), and the root
/// span carries a non-zero trace ID. Returns coverage numbers for the
/// caller to gate on.
pub fn validate_chrome(doc: &Json) -> anyhow::Result<ChromeReport> {
    use anyhow::{anyhow, bail};
    struct Ev {
        name: String,
        ts: u64,
        end: u64,
        dur: u64,
        tid: u64,
        trace_id: u64,
    }
    let arr = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("no traceEvents array"))?;
    if arr.is_empty() {
        bail!("trace has no events");
    }
    let mut evs = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let field = |k: &str| e.get(k).ok_or_else(|| anyhow!("event {i}: missing {k:?}"));
        let name = field("name")?
            .as_str()
            .filter(|n| !n.is_empty())
            .ok_or_else(|| anyhow!("event {i}: empty name"))?
            .to_string();
        if field("ph")?.as_str() != Some("X") {
            bail!("event {i} ({name}): ph must be \"X\"");
        }
        let num = |k: &str| -> anyhow::Result<u64> {
            field(k)?
                .as_u64()
                .ok_or_else(|| anyhow!("event {i} ({name}): {k:?} not a u64"))
        };
        let (ts, dur, tid) = (num("ts")?, num("dur")?, num("tid")?);
        let trace_id = field("args")?
            .get("trace_id")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("event {i} ({name}): missing args.trace_id"))?;
        evs.push(Ev { name, ts, end: ts + dur, dur, tid, trace_id });
    }

    // Per-thread nesting: sorted by (ts, widest-first), each event must
    // either start after the enclosing span ends or fit inside it.
    // Track each event's parent for the coverage computation.
    let mut order: Vec<usize> = (0..evs.len()).collect();
    order.sort_by_key(|&i| (evs[i].tid, evs[i].ts, std::cmp::Reverse(evs[i].dur)));
    let mut parent: Vec<Option<usize>> = vec![None; evs.len()];
    let mut stack: Vec<usize> = Vec::new();
    let mut prev_tid = None;
    for &i in &order {
        if prev_tid != Some(evs[i].tid) {
            stack.clear();
            prev_tid = Some(evs[i].tid);
        }
        while let Some(&top) = stack.last() {
            if evs[i].ts >= evs[top].end {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(&top) = stack.last() {
            if evs[i].end > evs[top].end + NEST_SLACK_US {
                bail!(
                    "span {:?} [{}..{}] overlaps {:?} [{}..{}] on tid {} without nesting",
                    evs[i].name, evs[i].ts, evs[i].end,
                    evs[top].name, evs[top].ts, evs[top].end,
                    evs[i].tid,
                );
            }
            parent[i] = Some(top);
        }
        stack.push(i);
    }

    // Pipeline hierarchies. pnr children share the parent's thread; dse
    // children may run on pool threads, so containment is by interval
    // within the same trace ID.
    let inside = |c: &Ev, p: &Ev| c.ts >= p.ts && c.end <= p.end + NEST_SLACK_US;
    for c in &evs {
        if let Some(want) = match c.name.as_str() {
            "pnr.place" | "pnr.assign" | "pnr.route" => Some("pnr"),
            "dse.plan" | "dse.score" | "dse.rank" => Some("dse"),
            "dse.rank.sort" | "dse.rank.frontier" => Some("dse.rank"),
            _ => None,
        } {
            let held = evs.iter().any(|p| {
                p.name == want
                    && inside(c, p)
                    && if want == "pnr" { p.tid == c.tid } else { p.trace_id == c.trace_id }
            });
            if !held {
                bail!("span {:?} [{}..{}] has no enclosing {want:?} span", c.name, c.ts, c.end);
            }
        }
    }

    let root = (0..evs.len())
        .max_by_key(|&i| evs[i].dur)
        .expect("non-empty");
    if evs[root].trace_id == 0 {
        bail!("root span {:?} carries no trace ID", evs[root].name);
    }
    let covered: u64 = (0..evs.len())
        .filter(|&i| parent[i] == Some(root))
        .map(|i| evs[i].dur)
        .sum();
    let root_coverage = if evs[root].dur == 0 {
        1.0
    } else {
        (covered as f64 / evs[root].dur as f64).min(1.0)
    };
    let mut ids: Vec<u64> = evs.iter().map(|e| e.trace_id).filter(|&t| t != 0).collect();
    ids.sort_unstable();
    ids.dedup();
    Ok(ChromeReport {
        events: evs.len(),
        root_name: evs[root].name.clone(),
        root_dur_us: evs[root].dur,
        root_coverage,
        trace_ids: ids.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `f` with recording on and a fresh trace ID installed; return
    /// the sink events belonging to that ID (other tests' events are
    /// invisible to us, ours to them).
    fn traced<R>(f: impl FnOnce() -> R) -> (u64, Vec<TraceEvent>, R) {
        set_enabled(true);
        let id = next_trace_id();
        let out = {
            let _ctx = TraceCtx::set(id);
            f()
        };
        let evs = snapshot_events()
            .into_iter()
            .filter(|e| e.trace_id == id)
            .collect();
        (id, evs, out)
    }

    #[test]
    fn spans_nest_and_carry_trace_id() {
        let (id, evs, ()) = traced(|| {
            let outer = Span::begin("outer", "test");
            {
                let inner = Span::begin("inner", "test");
                std::thread::sleep(std::time::Duration::from_millis(1));
                let ms = inner.end_ms();
                assert!(ms >= 1.0, "inner measured {ms} ms");
            }
            drop(outer);
        });
        let outer = evs.iter().find(|e| e.name == "outer").expect("outer recorded");
        let inner = evs.iter().find(|e| e.name == "inner").expect("inner recorded");
        assert_eq!(outer.trace_id, id);
        assert_eq!(inner.trace_id, id);
        assert_eq!(outer.tid, inner.tid);
        // child interval is contained in the parent interval (+2 µs
        // slack: ts and dur truncate to whole µs independently)
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 2);
    }

    #[test]
    fn trace_ctx_restores_previous_id() {
        let before = current_trace();
        {
            let _a = TraceCtx::set(777);
            assert_eq!(current_trace(), 777);
            {
                let _b = TraceCtx::set(888);
                assert_eq!(current_trace(), 888);
            }
            assert_eq!(current_trace(), 777);
        }
        assert_eq!(current_trace(), before);
    }

    #[test]
    fn disabled_spans_measure_but_record_nothing() {
        // Use a unique trace id while recording is forced on for other
        // tests; our span runs with recording *captured off* at begin.
        let id = next_trace_id();
        let _ctx = TraceCtx::set(id);
        let was = enabled();
        set_enabled(false);
        let s = Span::begin("ghost", "test");
        let ms = s.end_ms();
        set_enabled(was);
        assert!(ms >= 0.0);
        assert!(
            snapshot_events().iter().all(|e| e.trace_id != id),
            "disabled span must not reach the sink"
        );
    }

    #[test]
    fn chrome_export_is_well_formed() {
        let (_, evs, ()) = traced(|| {
            let s = Span::begin("exported", "test");
            drop(s);
        });
        let json = export_chrome(&evs).to_string();
        let v = crate::util::json::parse(&json).expect("export parses");
        let arr = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!arr.is_empty());
        for e in arr {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert!(e.get("name").unwrap().as_str().is_some());
            assert!(e.get("ts").unwrap().as_u64().is_some());
            assert!(e.get("dur").unwrap().as_u64().is_some());
            assert!(e.get("tid").unwrap().as_u64().is_some());
            assert!(e.get("args").unwrap().get("trace_id").is_some());
        }
    }

    fn ev(name: &str, ts: u64, dur: u64, tid: u64, trace_id: u64) -> Json {
        Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("cat", Json::Str("test".to_string())),
            ("ph", Json::Str("X".to_string())),
            ("ts", Json::num_u64(ts)),
            ("dur", Json::num_u64(dur)),
            ("pid", Json::num_u64(1)),
            ("tid", Json::num_u64(tid)),
            ("args", Json::obj(vec![("trace_id", Json::num_u64(trace_id))])),
        ])
    }

    fn doc(events: Vec<Json>) -> Json {
        Json::obj(vec![("traceEvents", Json::Arr(events))])
    }

    #[test]
    fn validator_accepts_a_nested_pipeline_trace() {
        // map ─┬ dse ─┬ dse.plan ┬ dse.rank
        //      │      └ dse.score (pool thread, same trace id)
        //      └ pnr ─┬ pnr.place ┬ pnr.assign ┬ pnr.route
        let d = doc(vec![
            ev("map", 0, 1000, 1, 7),
            ev("dse", 10, 400, 1, 7),
            ev("dse.plan", 20, 50, 1, 7),
            ev("dse.score", 80, 200, 2, 7),
            ev("dse.rank", 300, 80, 1, 7),
            ev("pnr", 420, 570, 1, 7),
            ev("pnr.place", 430, 200, 1, 7),
            ev("pnr.assign", 640, 150, 1, 7),
            ev("pnr.route", 800, 180, 1, 7),
        ]);
        let r = validate_chrome(&d).expect("valid trace");
        assert_eq!(r.root_name, "map");
        assert_eq!(r.events, 9);
        assert_eq!(r.trace_ids, 1);
        // direct children of map: dse (400) + pnr (570) over 1000 µs
        assert!((r.root_coverage - 0.97).abs() < 1e-9, "coverage {}", r.root_coverage);
    }

    #[test]
    fn validator_rejects_overlap_missing_parent_and_zero_trace_id() {
        // Partial overlap on one thread: [0..100] vs [50..150].
        let overlap = doc(vec![ev("a", 0, 100, 1, 1), ev("b", 50, 100, 1, 1)]);
        assert!(validate_chrome(&overlap).unwrap_err().to_string().contains("overlap"));

        // pnr.place with no enclosing pnr span on that thread.
        let orphan = doc(vec![ev("map", 0, 100, 1, 1), ev("pnr.place", 10, 20, 2, 1)]);
        assert!(validate_chrome(&orphan).unwrap_err().to_string().contains("pnr"));

        // Root without a trace ID fails the correlation requirement.
        let anon = doc(vec![ev("map", 0, 100, 1, 0)]);
        assert!(validate_chrome(&anon).unwrap_err().to_string().contains("trace ID"));

        // Child-end slack: 2 µs past the parent is truncation, not overlap.
        let slack = doc(vec![ev("map", 0, 100, 1, 1), ev("dse", 10, 92, 1, 1)]);
        assert!(validate_chrome(&slack).is_ok());
    }
}
