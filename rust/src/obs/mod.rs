//! `widesa::obs` — the observability layer: metrics, spans, trending.
//!
//! The ROADMAP's north star is a production serve stack, and a
//! production stack needs to answer "where did the time go" without
//! ad-hoc prints: which stage dominates a cold compile per workload
//! family, whether the DSE or the annealer is the tail, whether a
//! refactor moved the p99. Until this module the only visibility was the
//! single `StageTimings {place, assign, route}` triple and one-snapshot
//! `BENCH_*.json` files with no trajectory. Like everything else in the
//! crate, the layer is hand-rolled and dependency-free (the offline
//! vendor set has no `tracing`/`prometheus`), and cheap enough for the
//! serve hot path:
//!
//! * [`metrics`] — [`metrics::Registry`]: atomic counters and gauges
//!   plus **log2-bucketed latency histograms** (one `fetch_add` per
//!   record, p50/p99/p999 read out of the buckets). The serve layer owns
//!   a per-handle registry (its `ServeStats` counters *are* registry
//!   counters — one source of truth), and pipeline-level code (DSE,
//!   persistence) records into the process-global [`metrics::global`].
//! * [`trace`] — [`trace::Span`] RAII timers recording into a bounded
//!   per-thread event buffer that flushes to a shared sink whenever a
//!   thread's outermost span closes. Spans carry a **trace ID**
//!   propagated across the serve worker pools
//!   ([`trace::current_trace`] / [`trace::TraceCtx`]), so one request's
//!   spans correlate across threads. [`trace::export_chrome`] renders
//!   the sink as Chrome trace-event JSON — loadable in
//!   `chrome://tracing` or <https://ui.perfetto.dev>.
//! * [`trend`] — appends each CI run's p50/p99/p999 + per-stage ms to
//!   `BENCH_trend.jsonl` keyed by commit (`widesa trend`), turning the
//!   one-snapshot bench files into a per-commit trajectory.
//!
//! Span durations are also the **single source of truth for
//! `StageTimings`**: `place_route::compiler` builds its per-stage
//! timings from the values the spans measured, so the `stage_ms`
//! protocol field and a Chrome trace can never disagree.
//!
//! Tracing is off by default ([`trace::enabled`] is one relaxed atomic
//! load; a disabled [`trace::Span`] still measures time — callers that
//! feed `StageTimings` need the number — but records nothing).
//! `bench_serve_load` gates the instrumented-vs-uninstrumented p50 gap
//! at ≤5 %. See `docs/OBSERVABILITY.md` for the metric catalog, the
//! span hierarchy and the trend-file schema.

pub mod metrics;
pub mod trace;
pub mod trend;

pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use trace::{Span, TraceCtx};
