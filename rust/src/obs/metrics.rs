//! Atomic metrics: counters, gauges and log2-bucketed latency
//! histograms, grouped in a [`Registry`].
//!
//! Built for the serve hot path: recording is one `fetch_add` on an
//! `Arc`-shared cell (no lock, no allocation, no syscall); the only lock
//! in the module guards name → handle registration, which callers do
//! once and cache. Snapshots ([`Registry::snapshot`]) render as
//! canonical JSON (names sort via `BTreeMap`), so two snapshots of the
//! same state are byte-identical — the property the deterministic
//! concurrent-recording test pins.
//!
//! Histograms bucket by `floor(log2(v)) + 1` (bucket 0 holds exact
//! zeros; bucket *i* ≥ 1 holds `[2^(i-1), 2^i)`), the classic
//! HdrHistogram-lite shape: 65 buckets cover the whole `u64` range and
//! a quantile read costs one pass over them. The
//! `WIDESA_MUTATE=obs-bucket` seam shifts every bucket index up by one
//! so `make mutation-smoke` can prove the bucketing tests bite.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Number of histogram buckets: one for exact zeros plus one per power
/// of two up to `2^63` (so any `u64` value lands somewhere).
pub const HIST_BUCKETS: usize = 65;

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (stored as `f64` bits).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Index of the bucket holding `v`: 0 for 0, else `floor(log2 v) + 1`.
/// The `WIDESA_MUTATE=obs-bucket` mutation seam shifts the result up by
/// one (clamped), which mis-files every recorded value — the bucketing
/// guard tests must fail under it or they are not testing the bucketing.
fn bucket_index(v: u64) -> usize {
    let idx = (64 - v.leading_zeros()) as usize;
    idx + mutate_bucket_shift()
}

fn mutate_bucket_shift() -> usize {
    static SHIFT: OnceLock<usize> = OnceLock::new();
    *SHIFT.get_or_init(|| match std::env::var("WIDESA_MUTATE") {
        Ok(v) if v == "obs-bucket" => 1,
        _ => 0,
    })
}

/// Inclusive upper bound of bucket `i` (what quantile reads report —
/// conservative: a quantile is never under-reported).
fn bucket_upper(i: usize) -> u64 {
    match i {
        0 => 0,
        1..=63 => (1u64 << i) - 1,
        _ => u64::MAX,
    }
}

/// Log2-bucketed histogram of `u64` samples (latencies in µs, sizes in
/// bytes — unit is the caller's convention, the registry names carry a
/// `_us`/`_bytes` suffix).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, v: u64) {
        let idx = bucket_index(v).min(HIST_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in whole microseconds.
    pub fn record_us(&self, d: std::time::Duration) {
        self.record(d.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket counts (index, count) for non-empty buckets.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let n = c.load(Ordering::Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect()
    }

    /// Quantile estimate `q ∈ [0, 1]`: the inclusive upper bound of the
    /// bucket where the cumulative count crosses `ceil(q · total)`.
    /// Conservative by construction (never under-reports) and exact
    /// whenever all samples in the crossing bucket share a value. NaN on
    /// an empty histogram (renders as JSON `null`).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_upper(i) as f64;
            }
        }
        bucket_upper(HIST_BUCKETS - 1) as f64
    }

    fn to_json(&self) -> Json {
        let buckets = self
            .nonzero_buckets()
            .into_iter()
            .map(|(i, n)| Json::Arr(vec![Json::num_usize(i), Json::num_u64(n)]))
            .collect();
        Json::obj(vec![
            ("count", Json::num_u64(self.count())),
            ("sum", Json::num_u64(self.sum())),
            ("p50", Json::Num(self.quantile(0.50))),
            ("p99", Json::Num(self.quantile(0.99))),
            ("p999", Json::Num(self.quantile(0.999))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// A named set of metrics. Handles are `Arc`-shared: register once
/// (get-or-create under a short lock), then record lock-free forever.
///
/// The serve layer owns one registry per [`crate::ServeHandle`] (so
/// tests see deterministic counts) and pipeline-level modules share the
/// process-global [`global`] registry.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the counter named `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Get or create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Get or create the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Canonical JSON snapshot: `{"counters": {...}, "gauges": {...},
    /// "histograms": {...}}`, names sorted, byte-identical for identical
    /// state.
    pub fn snapshot(&self) -> Json {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::num_u64(v.get())))
            .collect::<BTreeMap<_, _>>();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(v.get())))
            .collect::<BTreeMap<_, _>>();
        let histograms = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect::<BTreeMap<_, _>>();
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("histograms".to_string(), Json::Obj(histograms)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// The process-global registry for code that has no handle to thread one
/// through (DSE, P&R, persistence). Serve-layer metrics live in the
/// per-handle registry instead — see [`crate::ServeHandle::metrics`].
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let r = Registry::new();
        let c = r.counter("a.count");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name → same cell
        assert_eq!(r.counter("a.count").get(), 5);
        let g = r.gauge("a.level");
        g.set(2.5);
        assert_eq!(r.gauge("a.level").get(), 2.5);
    }

    /// Mutation-smoke guard (`WIDESA_MUTATE=obs-bucket` must flip this):
    /// values land in the exact log2 bucket the scheme defines, and the
    /// quantile read reports the bucket's inclusive upper bound.
    #[test]
    fn histogram_bucketing_is_exact() {
        let h = Histogram::new();
        for v in [1u64, 1, 1, 1000] {
            h.record(v);
        }
        // 1 → bucket 1 (upper bound 1); 1000 → bucket 10 ([512, 1024))
        assert_eq!(h.nonzero_buckets(), vec![(1, 3), (10, 1)]);
        assert_eq!(h.quantile(0.5), 1.0, "p50 of {{1,1,1,1000}} is exactly 1");
        assert_eq!(h.quantile(1.0), 1023.0, "p100 reports bucket 10's upper bound");
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 1003);

        // zero gets its own bucket; boundaries fall on powers of two
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.nonzero_buckets(), vec![(0, 1), (1, 1), (2, 2), (3, 1)]);
    }

    #[test]
    fn quantiles_are_monotone_and_conservative() {
        let h = Histogram::new();
        for v in 0..1000u64 {
            h.record(v);
        }
        let (p50, p99, p999) = (h.quantile(0.5), h.quantile(0.99), h.quantile(0.999));
        assert!(p50 <= p99 && p99 <= p999);
        // conservative: the true p50 (499) is ≤ the reported bound, and
        // the bound is the enclosing bucket's top, not a wild number
        assert!((499.0..=1023.0).contains(&p50), "p50 = {p50}");
        assert!(p999 <= 1023.0);
        // empty histogram → NaN → JSON null
        let empty = Histogram::new();
        assert!(empty.quantile(0.5).is_nan());
        assert_eq!(Json::Num(empty.quantile(0.5)).to_string(), "null");
    }

    #[test]
    fn snapshot_is_deterministic_under_concurrent_recording() {
        // N threads × M ops against shared handles: every op must land
        // (atomics lose nothing), and two snapshots of the settled state
        // must be byte-identical.
        let r = Registry::new();
        let c = r.counter("work.total");
        let h = r.histogram("work.us");
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let (c, h) = (Arc::clone(&c), Arc::clone(&h));
                s.spawn(move || {
                    for i in 0..1000u64 {
                        c.inc();
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
        assert_eq!(h.count(), 8000);
        let total: u64 = h.nonzero_buckets().iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 8000, "bucket counts cover every sample");
        let a = r.snapshot().to_string();
        let b = r.snapshot().to_string();
        assert_eq!(a, b, "settled snapshots are byte-identical");
        // snapshot parses and exposes the canonical sections
        let v = crate::util::json::parse(&a).unwrap();
        assert_eq!(
            v.get("counters").unwrap().get("work.total").unwrap().as_u64(),
            Some(8000)
        );
        assert!(v.get("histograms").unwrap().get("work.us").is_some());
        assert!(v.get("gauges").is_some());
    }

    #[test]
    fn bucket_upper_bounds_tile_the_range() {
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(10), 1023);
        assert_eq!(bucket_upper(64), u64::MAX);
        // every value's bucket upper bound is ≥ the value, and the
        // previous bucket's bound is < the value (the buckets tile)
        for v in [1u64, 2, 3, 7, 8, 1000, u64::MAX / 2, u64::MAX] {
            let i = (64 - v.leading_zeros()) as usize;
            assert!(bucket_upper(i) >= v);
            assert!(bucket_upper(i - 1) < v);
        }
    }
}
