//! Per-commit bench trending: fold the one-snapshot `BENCH_serve.json` /
//! `BENCH_compile.json` files into an append-only `BENCH_trend.jsonl`
//! trajectory, one line per CI run keyed by commit.
//!
//! The snapshot files answer "how fast is it now"; the trend file
//! answers "which commit moved the p99" — the ROADMAP item this closes.
//! CI runs `widesa trend --commit $GITHUB_SHA` after the bench smokes so
//! every run appends exactly one line. The line shape (schema 3):
//!
//! ```json
//! {"schema":3,"commit":"<sha>","ts":<unix-s>,
//!  "serve":{"p50_us":…,"p99_us":…,"p999_us":…,"shed_rate":…,
//!           "overhead_p50_pct":…,"stage_ms":{"place":…,"assign":…,"route":…}},
//!  "compile":{"cold_ms":{…},"anneal_speedup":…},
//!  "energy":{"mm_f32_tops_per_watt":…},
//!  "blocking":{"speedup":…,"large_n_gflops":…,"dram_model_err_pct":…}}
//! ```
//!
//! Schema 2 added the `energy` section: the fp32 MM 8192³ TOPS/W from
//! the shared analytic cost + power model, so efficiency regressions
//! trend per commit alongside latency (`docs/ENERGY.md`). Schema 3 added
//! the `blocking` section from `BENCH_blocking.json` (`make
//! blocking-smoke`): the large-N blocked-replay speedup over the naive
//! driver, the large-N functional GF/s point, and the predicted-vs-
//! measured DRAM model error (`docs/BLOCKING.md`). Readers accept both
//! eras — a schema-2 line simply has no `blocking` key, exactly like any
//! other skipped lane.
//!
//! Missing inputs (file absent, or a seed schema full of `null`s) render
//! as `null` fields rather than failing: a trend line that says "no
//! measurement this run" is itself information, and CI must not go red
//! because one bench lane was skipped.

use crate::util::json::{parse, Json};
use anyhow::{Context, Result};
use std::path::Path;

/// Version stamp on every trend line; bump on shape changes so readers
/// can split the file by era.
pub const TREND_SCHEMA: u32 = 3;

/// Copy `key` out of `src` (or `Json::Null` when absent/`src` is None).
fn lift(src: Option<&Json>, key: &str) -> Json {
    src.and_then(|v| v.get(key)).cloned().unwrap_or(Json::Null)
}

/// Build one trend line from the two bench snapshots plus the analytic
/// fp32 MM TOPS/W datum. Pure — callers supply the commit, timestamp and
/// the efficiency number, so tests are byte-exact.
pub fn trend_line(
    commit: &str,
    unix_ts: u64,
    serve: Option<&Json>,
    compile: Option<&Json>,
    mm_f32_tops_per_watt: Option<f64>,
    blocking: Option<&Json>,
) -> Json {
    let serve_part = Json::obj(vec![
        ("p50_us", lift(serve, "p50_us")),
        ("p99_us", lift(serve, "p99_us")),
        ("p999_us", lift(serve, "p999_us")),
        ("shed_rate", lift(serve, "shed_rate")),
        (
            "overhead_p50_pct",
            serve
                .and_then(|v| v.get("obs_overhead"))
                .map(|o| lift(Some(o), "p50_pct"))
                .unwrap_or(Json::Null),
        ),
        ("stage_ms", lift(serve, "stage_ms")),
    ]);
    let compile_part = Json::obj(vec![
        ("cold_ms", lift(compile, "cold_ms")),
        (
            "anneal_speedup",
            compile
                .and_then(|v| v.get("anneal"))
                .map(|a| lift(Some(a), "speedup"))
                .unwrap_or(Json::Null),
        ),
    ]);
    let energy_part = Json::obj(vec![(
        "mm_f32_tops_per_watt",
        mm_f32_tops_per_watt.map_or(Json::Null, Json::Num),
    )]);
    let blocking_part = Json::obj(vec![
        ("speedup", lift(blocking, "speedup")),
        ("large_n_gflops", lift(blocking, "large_n_gflops")),
        ("dram_model_err_pct", lift(blocking, "dram_model_err_pct")),
    ]);
    Json::obj(vec![
        ("schema", Json::num_u64(u64::from(TREND_SCHEMA))),
        ("commit", Json::str(commit)),
        ("ts", Json::num_u64(unix_ts)),
        ("serve", serve_part),
        ("compile", compile_part),
        ("energy", energy_part),
        ("blocking", blocking_part),
    ])
}

/// Read a bench snapshot if it exists and parses; `None` otherwise
/// (trend lines degrade to nulls, they don't fail the run).
pub fn read_bench(path: &Path) -> Option<Json> {
    let text = std::fs::read_to_string(path).ok()?;
    parse(text.trim()).ok()
}

/// Append `line` to the JSONL trend file at `path` (created if absent).
pub fn append_trend(path: &Path, line: &Json) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("open trend file {}", path.display()))?;
    writeln!(f, "{line}").with_context(|| format!("append trend line to {}", path.display()))?;
    Ok(())
}

/// Parse every line of a trend file, skipping blanks and the
/// seed-schema comment convention (lines whose `commit` is `"seed"` are
/// kept — they are valid lines — but unparseable lines are errors: an
/// append-only file that rots silently is worse than none).
pub fn parse_trend(text: &str) -> Result<Vec<Json>> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(|l| parse(l).map_err(|e| anyhow::anyhow!("bad trend line: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serve_snapshot() -> Json {
        parse(
            r#"{"p50_us":1200.0,"p99_us":9000.0,"p999_us":21000.0,"shed_rate":0.01,
                "obs_overhead":{"p50_pct":1.7},
                "stage_ms":{"place":3.0,"assign":1.0,"route":2.0}}"#,
        )
        .unwrap()
    }

    fn compile_snapshot() -> Json {
        parse(r#"{"cold_ms":{"mm-400":45.0},"anneal":{"speedup":2.4}}"#).unwrap()
    }

    fn blocking_snapshot() -> Json {
        parse(r#"{"n":2048,"speedup":2.8,"large_n_gflops":41.5,"dram_model_err_pct":0.0}"#)
            .unwrap()
    }

    #[test]
    fn trend_line_is_deterministic_and_complete() {
        let a = trend_line(
            "abc123",
            1_700_000_000,
            Some(&serve_snapshot()),
            Some(&compile_snapshot()),
            Some(0.074),
            Some(&blocking_snapshot()),
        );
        let b = trend_line(
            "abc123",
            1_700_000_000,
            Some(&serve_snapshot()),
            Some(&compile_snapshot()),
            Some(0.074),
            Some(&blocking_snapshot()),
        );
        assert_eq!(a.to_string(), b.to_string(), "same inputs → byte-identical line");
        assert_eq!(a.get("schema").unwrap().as_u64(), Some(u64::from(TREND_SCHEMA)));
        assert_eq!(a.get("commit").unwrap().as_str(), Some("abc123"));
        let serve = a.get("serve").unwrap();
        assert_eq!(serve.get("p50_us").unwrap().as_f64(), Some(1200.0));
        assert_eq!(serve.get("overhead_p50_pct").unwrap().as_f64(), Some(1.7));
        assert_eq!(
            serve.get("stage_ms").unwrap().get("route").unwrap().as_f64(),
            Some(2.0)
        );
        let compile = a.get("compile").unwrap();
        assert_eq!(
            compile.get("cold_ms").unwrap().get("mm-400").unwrap().as_f64(),
            Some(45.0)
        );
        assert_eq!(compile.get("anneal_speedup").unwrap().as_f64(), Some(2.4));
        assert_eq!(
            a.get("energy").unwrap().get("mm_f32_tops_per_watt").unwrap().as_f64(),
            Some(0.074)
        );
        let blocking = a.get("blocking").unwrap();
        assert_eq!(blocking.get("speedup").unwrap().as_f64(), Some(2.8));
        assert_eq!(blocking.get("large_n_gflops").unwrap().as_f64(), Some(41.5));
        assert_eq!(blocking.get("dram_model_err_pct").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn missing_inputs_degrade_to_nulls() {
        let line = trend_line("seed", 0, None, None, None, None);
        assert_eq!(line.get("serve").unwrap().get("p50_us"), Some(&Json::Null));
        assert_eq!(line.get("compile").unwrap().get("cold_ms"), Some(&Json::Null));
        assert_eq!(
            line.get("energy").unwrap().get("mm_f32_tops_per_watt"),
            Some(&Json::Null)
        );
        assert_eq!(
            line.get("blocking").unwrap().get("large_n_gflops"),
            Some(&Json::Null)
        );
        // the line still parses back
        let rt = parse(&line.to_string()).unwrap();
        assert_eq!(rt.get("commit").unwrap().as_str(), Some("seed"));
    }

    #[test]
    fn readers_accept_schema_two_and_three_eras() {
        // A real schema-2 line (no blocking key, as written before the
        // bump) must coexist with schema-3 lines in one trend file.
        let old = r#"{"schema":2,"commit":"old","ts":1,"serve":{"p50_us":900.0},
                      "compile":{"cold_ms":null},"energy":{"mm_f32_tops_per_watt":0.07}}"#
            .replace('\n', " ");
        let new = trend_line("new", 2, None, None, None, Some(&blocking_snapshot()));
        let text = format!("{old}\n{new}\n");
        let lines = parse_trend(&text).unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].get("schema").unwrap().as_u64(), Some(2));
        assert!(lines[0].get("blocking").is_none(), "old era has no blocking");
        assert_eq!(lines[1].get("schema").unwrap().as_u64(), Some(3));
        assert_eq!(
            lines[1].get("blocking").unwrap().get("speedup").unwrap().as_f64(),
            Some(2.8)
        );
    }

    #[test]
    fn append_and_parse_round_trip() {
        let dir = std::env::temp_dir().join(format!("widesa-trend-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_trend.jsonl");
        let _ = std::fs::remove_file(&path);
        for i in 0..3u64 {
            let line = trend_line(&format!("c{i}"), i, Some(&serve_snapshot()), None, None, None);
            append_trend(&path, &line).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines = parse_trend(&text).unwrap();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].get("commit").unwrap().as_str(), Some("c2"));
        assert!(parse_trend("not json\n").is_err());
        let _ = std::fs::remove_file(&path);
    }
}
