//! Vitis DSP library baselines for 2D-FFT and FIR (paper §V-B).
//!
//! The open-source DSP-library designs instantiate small fixed graphs
//! (10 AIEs) per kernel; they are latency-oriented, not
//! throughput-oriented, which is why their aggregate TOPS are far below
//! a 256–320-core WideSA mapping despite competitive per-AIE efficiency
//! on the integer types. Sustained efficiencies are calibrated to the
//! published Table III rows.

use crate::arch::aie::AieCore;
use crate::baselines::BaselinePoint;
use crate::recurrence::dtype::DType;

pub const DSPLIB_AIES: u32 = 10;

/// Sustained efficiency of the DSP-lib FFT graphs.
fn fft_eff(dtype: DType) -> f64 {
    match dtype {
        DType::CF32 => 0.20,  // 0.04 / (10 · 0.020)
        DType::CI16 => 0.163, // 0.13 / (10 · 0.080)
        _ => 0.15,
    }
}

/// Sustained efficiency of the DSP-lib FIR graphs.
fn fir_eff(dtype: DType) -> f64 {
    match dtype {
        DType::F32 => 0.75,  // 0.15 / (10 · 0.020)
        DType::I8 => 0.80,   // 2.56 / (10 · 0.320)
        DType::I16 => 0.775, // 0.62 / (10 · 0.080)
        DType::CF32 => 0.75, // 0.15 / (10 · 0.020)
        _ => 0.7,
    }
}

pub fn fft_point(dtype: DType) -> BaselinePoint {
    let core = AieCore::default();
    BaselinePoint {
        name: "Vitis DSPLib",
        aies: DSPLIB_AIES,
        tops: DSPLIB_AIES as f64 * core.peak_ops(dtype) / 1e12 * fft_eff(dtype),
    }
}

pub fn fir_point(dtype: DType) -> BaselinePoint {
    let core = AieCore::default();
    BaselinePoint {
        name: "Vitis DSPLib",
        aies: DSPLIB_AIES,
        tops: DSPLIB_AIES as f64 * core.peak_ops(dtype) / 1e12 * fir_eff(dtype),
    }
}

/// Published Table III baseline rows for calibration checks.
pub fn paper_point(kind: &str, dtype: DType) -> Option<f64> {
    match (kind, dtype) {
        ("fft", DType::CF32) => Some(0.04),
        ("fft", DType::CI16) => Some(0.13),
        ("fir", DType::F32) => Some(0.15),
        ("fir", DType::I8) => Some(2.56),
        ("fir", DType::I16) => Some(0.62),
        ("fir", DType::CF32) => Some(0.15),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_rows_match_published() {
        for d in [DType::CF32, DType::CI16] {
            let got = fft_point(d).tops;
            let want = paper_point("fft", d).unwrap();
            assert!((got - want).abs() / want < 0.15, "{d}: {got:.3} vs {want}");
        }
    }

    #[test]
    fn fir_rows_match_published() {
        for d in [DType::F32, DType::I8, DType::I16, DType::CF32] {
            let got = fir_point(d).tops;
            let want = paper_point("fir", d).unwrap();
            assert!((got - want).abs() / want < 0.15, "{d}: {got:.3} vs {want}");
        }
    }

    #[test]
    fn per_aie_efficiency_sane() {
        // DSP-lib FIR per-AIE beats WideSA per-AIE (the paper's trade-off
        // discussion): small graphs keep each core busier.
        let p = fir_point(DType::F32);
        assert!(p.tops_per_aie() > 0.012);
    }
}
