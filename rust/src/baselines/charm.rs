//! CHARM (Zhuang et al., FPGA'23) MM baseline model.
//!
//! CHARM composes two monolithic 384-AIE MM accelerators on the VC1902.
//! Its per-AIE sustained efficiency is essentially the same AIE
//! microkernel as WideSA's (both >95 % utilisation of the cores they
//! claim); WideSA's edge comes from *using more of the array* (400 vs
//! 384) plus slightly better staging — the ≈1.11× of the abstract. The
//! model: CHARM TOPS = 384 cores × peak(dtype) × issue_eff(dtype) ×
//! monolithic-overhead, with the overhead calibrated once against the
//! published fp32 number (3.73 TOPS) and reused across dtypes.

use crate::arch::aie::AieCore;
use crate::baselines::BaselinePoint;
use crate::mapping::candidate::Kind;
use crate::mapping::cost::issue_efficiency;
use crate::recurrence::dtype::DType;

pub const CHARM_AIES: u32 = 384;
/// Staging overhead of the dual-monolithic design vs WideSA's movers
/// (calibrated at fp32: 3.73 / (384 · 0.020 · 0.52) ≈ 0.934).
pub const MONOLITHIC_OVERHEAD: f64 = 0.934;

pub fn mm_tops(dtype: DType) -> f64 {
    let core = AieCore::default();
    CHARM_AIES as f64 * core.peak_ops(dtype) / 1e12
        * issue_efficiency(Kind::Mm, dtype)
        * MONOLITHIC_OVERHEAD
}

pub fn mm_point(dtype: DType) -> BaselinePoint {
    BaselinePoint {
        name: "CHARM",
        aies: CHARM_AIES,
        tops: mm_tops(dtype),
    }
}

/// The paper's published CHARM rows (Table III) for calibration checks.
pub fn paper_mm_tops(dtype: DType) -> Option<f64> {
    match dtype {
        DType::F32 => Some(3.73),
        DType::I8 => Some(29.78),
        DType::I16 => Some(7.82),
        DType::I32 => Some(3.72),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_matches_published_rows() {
        for d in [DType::F32, DType::I8, DType::I16, DType::I32] {
            let got = mm_tops(d);
            let want = paper_mm_tops(d).unwrap();
            let rel = (got - want).abs() / want;
            assert!(rel < 0.10, "{d}: model {got:.2} vs paper {want:.2}");
        }
    }

    #[test]
    fn charm_slower_than_full_array_widesa() {
        // WideSA at 400 AIEs with the same kernel eff must beat CHARM's 384.
        let core = AieCore::default();
        for d in [DType::F32, DType::I8] {
            let widesa = 400.0 * core.peak_ops(d) / 1e12 * issue_efficiency(Kind::Mm, d);
            assert!(widesa / mm_tops(d) > 1.08, "{d}");
        }
    }
}
