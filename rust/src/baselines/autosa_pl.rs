//! AutoSA PL-only systolic arrays (Table IV baseline).
//!
//! AutoSA (Wang et al., FPGA'21) generates PL systolic arrays; on the
//! VCK5000's 1968 DSP58s the paper reports ~1536 DSPs at the listed
//! throughputs. The model: TOPS = DSPs × sustained-MACs-per-DSP × 2 ×
//! f_pl, with MACs/DSP calibrated per dtype against Table IV (DSP58s
//! pack multiple narrow MACs: ~6 int8 MACs per slice in vector mode, one
//! fp32 MAC via the hardened FP32 path at ~64 % sustained).

use crate::arch::power::{pl_only_dsps, PowerModel};
use crate::recurrence::dtype::DType;

/// PL clock AutoSA's generated arrays close timing at on this part.
pub const AUTOSA_FREQ_HZ: f64 = 300e6;

/// Sustained MACs per DSP58 per cycle (calibrated to Table IV).
pub fn macs_per_dsp(dtype: DType) -> f64 {
    match dtype {
        DType::F32 => 0.64,
        DType::I8 => 6.29,
        DType::I16 => 2.37,
        DType::I32 => 0.65,
        DType::CF32 => 0.16,
        DType::CI16 => 0.60,
    }
}

#[derive(Debug, Clone)]
pub struct PlOnlyDesign {
    pub dtype: DType,
    pub dsps: u32,
    pub tops: f64,
    pub power_w: f64,
    pub tops_per_watt: f64,
}

pub fn design(dtype: DType) -> PlOnlyDesign {
    let dsps = pl_only_dsps(dtype);
    let tops = dsps as f64 * macs_per_dsp(dtype) * 2.0 * AUTOSA_FREQ_HZ / 1e12;
    let power = PowerModel::default();
    let act = crate::arch::power::ActivityProfile {
        aies: 0,
        dsps,
        plio_channels: 0,
        dram_gbs: 60.0,
        aie_occupancy: 0.0,
    };
    let w = power.total_w(&act);
    PlOnlyDesign {
        dtype,
        dsps,
        tops,
        power_w: w,
        tops_per_watt: tops / w,
    }
}

/// Published Table IV PL-only rows for calibration checks.
pub fn paper_tops(dtype: DType) -> Option<f64> {
    match dtype {
        DType::F32 => Some(0.59),
        DType::I8 => Some(5.77),
        DType::I16 => Some(2.16),
        DType::I32 => Some(0.60),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tops_match_published_rows() {
        for d in [DType::F32, DType::I8, DType::I16, DType::I32] {
            let got = design(d).tops;
            let want = paper_tops(d).unwrap();
            assert!((got - want).abs() / want < 0.10, "{d}: {got:.3} vs {want}");
        }
    }

    #[test]
    fn power_near_19w() {
        for d in [DType::F32, DType::I8] {
            let w = design(d).power_w;
            assert!((w - 19.0).abs() < 2.0, "{d}: {w} W");
        }
    }

    #[test]
    fn dsp_budget_respected() {
        for d in [DType::F32, DType::I8, DType::I16, DType::I32] {
            assert!(design(d).dsps <= 1968);
        }
    }
}
