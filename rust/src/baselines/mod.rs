//! Baseline accelerator models the paper compares against (Table III/IV).
//!
//! Each baseline is reconstructed *at its published operating point* from
//! the numbers in the paper and the cited works (DESIGN.md §1): CHARM
//! (MM, FPGA'23), the Vitis-AI DPU / XVDPU (int8 2D-Conv, FPL'22), the
//! Vitis DSP library (2D-FFT + FIR), and AutoSA PL-only systolic arrays
//! (Table IV). The models are analytic — AIE/DSP counts, clocks and
//! sustained-efficiency parameters — so the comparison *shape* (who wins,
//! by what factor) is preserved without the authors' testbed.

pub mod autosa_pl;
pub mod charm;
pub mod dpu;
pub mod dsplib;

use crate::recurrence::dtype::DType;

/// A baseline's reported operating point for one benchmark row.
#[derive(Debug, Clone)]
pub struct BaselinePoint {
    pub name: &'static str,
    pub aies: u32,
    pub tops: f64,
}

impl BaselinePoint {
    pub fn tops_per_aie(&self) -> f64 {
        if self.aies == 0 {
            0.0
        } else {
            self.tops / self.aies as f64
        }
    }
}

/// Look up the Table III baseline for a benchmark family + dtype.
pub fn table3_baseline(kind: crate::mapping::candidate::Kind, dtype: DType) -> Option<BaselinePoint> {
    use crate::mapping::candidate::Kind;
    match kind {
        Kind::Mm => Some(charm::mm_point(dtype)),
        Kind::Conv2d => dpu::conv_point(dtype),
        Kind::Fft2d => Some(dsplib::fft_point(dtype)),
        Kind::Fir => Some(dsplib::fir_point(dtype)),
        // the expanded catalog and the CA mapping arm have no published
        // Table III baseline row (CA variants compare against the
        // standard-form winner instead — see eval/ca.rs)
        Kind::DwConv2d | Kind::Trsv | Kind::Stencil | Kind::CaMm => None,
    }
}
