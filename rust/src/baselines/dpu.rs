//! Vitis-AI DPU (XVDPU, FPL'22) int8 2D-Conv baseline.
//!
//! The released 8-PE DPU uses 256 AIEs at 1.33 GHz with the PL at
//! 350 MHz and only supports int8 (paper §V-B). Its sustained conv
//! efficiency is higher per-AIE than WideSA's (0.123 vs 0.090 TOPS/AIE)
//! because the DPU's hand-tuned conv engine overlaps weight loading
//! perfectly — but it cannot scale past its 256-core floorplan, which is
//! how WideSA wins overall (36.02 vs 31.40 TOPS).

use crate::baselines::BaselinePoint;
use crate::recurrence::dtype::DType;

pub const DPU_AIES: u32 = 256;
pub const DPU_FREQ_HZ: f64 = 1.33e9;
/// Sustained conv efficiency of the DPU conv engine (calibrated:
/// 31.40 / (256 · 128 · 2 · 1.33 GHz) ≈ 0.360).
pub const DPU_EFFICIENCY: f64 = 0.360;

pub fn conv_tops() -> f64 {
    DPU_AIES as f64 * 128.0 * 2.0 * DPU_FREQ_HZ * DPU_EFFICIENCY / 1e12
}

/// Only the int8 row exists (the DPU supports nothing else).
pub fn conv_point(dtype: DType) -> Option<BaselinePoint> {
    (dtype == DType::I8).then(|| BaselinePoint {
        name: "Vitis-AI DPU",
        aies: DPU_AIES,
        tops: conv_tops(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_published_point() {
        let got = conv_tops();
        assert!((got - 31.40).abs() / 31.40 < 0.05, "model {got:.2} vs 31.40");
    }

    #[test]
    fn only_int8_supported() {
        assert!(conv_point(DType::I8).is_some());
        assert!(conv_point(DType::F32).is_none());
        assert!(conv_point(DType::I16).is_none());
    }
}
