//! Affine expressions and maps over loop iterators.
//!
//! An [`AffineExpr`] is `Σ coeff_i · iter_i + constant`; an [`AffineMap`]
//! is a tuple of expressions — the representation used for array accesses
//! (e.g. `A[i][k]` in MM is the map `{ (i,j,k) -> (i,k) }`) and for the
//! linear part of schedule transforms.

use std::fmt;

/// `Σ coeffs[i] · iter_i + constant`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct AffineExpr {
    pub coeffs: Vec<i64>,
    pub constant: i64,
}

impl AffineExpr {
    pub fn new(coeffs: Vec<i64>, constant: i64) -> Self {
        Self { coeffs, constant }
    }

    /// The expression selecting iterator `i` out of `n`.
    pub fn var(i: usize, n: usize) -> Self {
        let mut coeffs = vec![0; n];
        coeffs[i] = 1;
        Self::new(coeffs, 0)
    }

    pub fn constant(c: i64, n: usize) -> Self {
        Self::new(vec![0; n], c)
    }

    /// Evaluate at an integer point.
    pub fn eval(&self, point: &[i64]) -> i64 {
        debug_assert_eq!(point.len(), self.coeffs.len());
        self.constant
            + self
                .coeffs
                .iter()
                .zip(point)
                .map(|(c, p)| c * p)
                .sum::<i64>()
    }

    pub fn num_dims(&self) -> usize {
        self.coeffs.len()
    }

    /// Apply to a *vector* (differences of points): the constant drops out.
    pub fn eval_vector(&self, v: &[i64]) -> i64 {
        self.coeffs.iter().zip(v).map(|(c, p)| c * p).sum()
    }
}

impl fmt::Display for AffineExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (i, c) in self.coeffs.iter().enumerate() {
            if *c == 0 {
                continue;
            }
            if !first {
                write!(f, " + ")?;
            }
            if *c == 1 {
                write!(f, "i{i}")?;
            } else {
                write!(f, "{c}·i{i}")?;
            }
            first = false;
        }
        if self.constant != 0 || first {
            if !first {
                write!(f, " + ")?;
            }
            write!(f, "{}", self.constant)?;
        }
        Ok(())
    }
}

/// A tuple of affine expressions: `{ iters -> (e_0, ..., e_{m-1}) }`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct AffineMap {
    pub exprs: Vec<AffineExpr>,
}

impl AffineMap {
    pub fn new(exprs: Vec<AffineExpr>) -> Self {
        Self { exprs }
    }

    /// Identity map on `n` iterators.
    pub fn identity(n: usize) -> Self {
        Self::new((0..n).map(|i| AffineExpr::var(i, n)).collect())
    }

    /// Map selecting (and optionally offsetting) a subset of iterators:
    /// output d reads iterator `dims[d]` plus `offsets[d]`.
    pub fn select(dims: &[usize], offsets: &[i64], n: usize) -> Self {
        debug_assert_eq!(dims.len(), offsets.len());
        Self::new(
            dims.iter()
                .zip(offsets)
                .map(|(&d, &o)| {
                    let mut e = AffineExpr::var(d, n);
                    e.constant = o;
                    e
                })
                .collect(),
        )
    }

    pub fn num_results(&self) -> usize {
        self.exprs.len()
    }

    pub fn num_dims(&self) -> usize {
        self.exprs.first().map_or(0, AffineExpr::num_dims)
    }

    pub fn eval(&self, point: &[i64]) -> Vec<i64> {
        self.exprs.iter().map(|e| e.eval(point)).collect()
    }

    pub fn eval_vector(&self, v: &[i64]) -> Vec<i64> {
        self.exprs.iter().map(|e| e.eval_vector(v)).collect()
    }

    /// Linear-part matrix (rows = results).
    pub fn matrix(&self) -> Vec<Vec<i64>> {
        self.exprs.iter().map(|e| e.coeffs.clone()).collect()
    }

    /// Is the linear part a permutation matrix (each row/col one ±1)?
    pub fn is_permutation(&self) -> bool {
        let m = self.matrix();
        if m.len() != self.num_dims() {
            return false;
        }
        let n = m.len();
        let mut col_seen = vec![false; n];
        for row in &m {
            let nz: Vec<usize> = (0..n).filter(|&j| row[j] != 0).collect();
            if nz.len() != 1 || row[nz[0]].abs() != 1 || col_seen[nz[0]] {
                return false;
            }
            col_seen[nz[0]] = true;
        }
        true
    }

    /// Determinant of the (square) linear part — Bareiss fraction-free
    /// elimination, exact over i64 for the small matrices used here.
    pub fn determinant(&self) -> Option<i64> {
        let mut m = self.matrix();
        let n = m.len();
        if n == 0 || m.iter().any(|r| r.len() != n) {
            return None;
        }
        let mut sign = 1i64;
        let mut prev = 1i64;
        for k in 0..n {
            if m[k][k] == 0 {
                match (k + 1..n).find(|&r| m[r][k] != 0) {
                    Some(swap) => {
                        m.swap(k, swap);
                        sign = -sign;
                    }
                    None => return Some(0), // singular
                }
            }
            for i in k + 1..n {
                for j in k + 1..n {
                    m[i][j] = (m[i][j] * m[k][k] - m[i][k] * m[k][j]) / prev;
                }
                m[i][k] = 0;
            }
            prev = m[k][k];
        }
        Some(sign * m[n - 1][n - 1])
    }

    /// Unimodular ⇔ |det| == 1 (legal loop-nest transformation basis).
    pub fn is_unimodular(&self) -> bool {
        self.determinant().map(i64::abs) == Some(1)
    }
}

impl fmt::Display for AffineMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, e) in self.exprs.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{e}")?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_affine_expr() {
        // 2i + 3j - 1
        let e = AffineExpr::new(vec![2, 3], -1);
        assert_eq!(e.eval(&[4, 5]), 2 * 4 + 3 * 5 - 1);
        assert_eq!(e.eval_vector(&[1, 1]), 5); // constant drops
    }

    #[test]
    fn identity_and_select() {
        let id = AffineMap::identity(3);
        assert_eq!(id.eval(&[7, 8, 9]), vec![7, 8, 9]);
        // A[i][k] access in MM: select dims 0, 2 of (i,j,k)
        let a = AffineMap::select(&[0, 2], &[0, 0], 3);
        assert_eq!(a.eval(&[7, 8, 9]), vec![7, 9]);
        // offset access x[i + 1]
        let x = AffineMap::select(&[0], &[1], 2);
        assert_eq!(x.eval(&[4, 0]), vec![5]);
    }

    #[test]
    fn permutation_detection() {
        let id = AffineMap::identity(3);
        assert!(id.is_permutation());
        let perm = AffineMap::new(vec![
            AffineExpr::var(2, 3),
            AffineExpr::var(0, 3),
            AffineExpr::var(1, 3),
        ]);
        assert!(perm.is_permutation());
        let skew = AffineMap::new(vec![
            AffineExpr::new(vec![1, 1], 0),
            AffineExpr::new(vec![0, 1], 0),
        ]);
        assert!(!skew.is_permutation());
    }

    #[test]
    fn determinant_and_unimodularity() {
        let skew = AffineMap::new(vec![
            AffineExpr::new(vec![1, 1], 0),
            AffineExpr::new(vec![0, 1], 0),
        ]);
        assert_eq!(skew.determinant(), Some(1));
        assert!(skew.is_unimodular());
        let scale = AffineMap::new(vec![
            AffineExpr::new(vec![2, 0], 0),
            AffineExpr::new(vec![0, 1], 0),
        ]);
        assert_eq!(scale.determinant(), Some(2));
        assert!(!scale.is_unimodular());
        let singular = AffineMap::new(vec![
            AffineExpr::new(vec![1, 1], 0),
            AffineExpr::new(vec![2, 2], 0),
        ]);
        assert_eq!(singular.determinant(), Some(0));
    }

    #[test]
    fn determinant_3x3_with_pivot() {
        let m = AffineMap::new(vec![
            AffineExpr::new(vec![0, 1, 0], 0),
            AffineExpr::new(vec![1, 0, 0], 0),
            AffineExpr::new(vec![0, 0, 1], 0),
        ]);
        assert_eq!(m.determinant(), Some(-1));
        assert!(m.is_unimodular());
    }
}
