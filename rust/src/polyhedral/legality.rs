//! Schedule legality: exact lexicographic checks on dependence vectors.
//!
//! For uniform recurrences a loop order is a legal sequential schedule iff
//! every non-zero dependence vector is lexicographically positive in that
//! order; a space-time mapping is legal iff, additionally, every
//! dependence with a non-zero *space* component is realisable as a
//! neighbour (|component| ≤ 1) transfer whose time projection is strictly
//! positive (the cycle that carries the datum).

use super::dependence::Dependence;
use super::schedule::{LoopNest, LoopRole};

/// Lexicographically positive (first non-zero component > 0)?
pub fn lex_positive(v: &[i64]) -> bool {
    for &c in v {
        if c > 0 {
            return true;
        }
        if c < 0 {
            return false;
        }
    }
    false // all-zero: not strictly positive
}

/// Lexicographically non-negative (zero allowed)?
pub fn lex_nonnegative(v: &[i64]) -> bool {
    v.iter().all(|&c| c == 0) || lex_positive(v)
}

/// Is the current loop order a legal sequential schedule?
pub fn is_legal_order(deps: &[Dependence]) -> bool {
    deps.iter().all(|d| lex_nonnegative(&d.vector))
}

/// Legality of a space-time *mapping* whose first `n_space` loops are the
/// space loops (the orientation [`crate::mapping::spacetime::enumerate`]
/// produces). A dependence is realisable iff either
///
/// * its full vector is lexicographically non-negative — the sequential
///   realisation: the linearised (space-outermost) order executes it in
///   program order, which is how MM's k-chaining and every componentwise
///   non-negative dependence has always been realised here; or
/// * it is a **neighbour transfer**: every space component has
///   |component| ≤ 1 (adjacent-core NoC/DMA links only) and the time
///   projection advances — strictly (lex-positive) for flow/output
///   dependences, which move a computed value between cores, and
///   non-negatively for read dependences, whose forwarding inserts the
///   unit pipeline step itself (see `graph::builder`).
///
/// The first clause alone is the pre-stencil behaviour, so nothing that
/// was legal becomes illegal. The second clause admits the negative
/// spatial offsets of stencil chains (`A[t−1, i±1, j±1]` ⇒ vectors like
/// `(−1, 0, 1, …)` after the space permutation) that *no* permutation can
/// make lexicographically non-negative: the value hops one core against
/// the iteration order while the sweep index advances in time — a plain
/// pipelined neighbour transfer on the array.
pub fn is_legal_mapping(deps: &[Dependence], n_space: usize) -> bool {
    deps.iter().all(|d| {
        if lex_nonnegative(&d.vector) {
            return true;
        }
        let n_space = n_space.min(d.vector.len());
        let (sp, tp) = d.vector.split_at(n_space);
        if sp.iter().any(|&c| c.abs() > 1) {
            return false; // non-neighbour space hop
        }
        match d.kind {
            super::dependence::DepKind::Read => lex_nonnegative(tp),
            _ => lex_positive(tp),
        }
    })
}

/// Space-time legality for a systolic mapping (paper §III-B-1):
/// * every dependence space projection must have |component| ≤ 1 on each
///   space loop (neighbour-to-neighbour NoC/DMA links only);
/// * any dependence that moves in space or carries a value must advance
///   strictly in time (its time projection is lex-positive), otherwise it
///   cannot be realised by a pipelined array.
pub fn is_legal_spacetime(nest: &LoopNest) -> bool {
    let space = nest.loops_with_role(LoopRole::Space);
    let time: Vec<usize> = (0..nest.rank())
        .filter(|i| {
            matches!(
                nest.roles[*i],
                LoopRole::Time | LoopRole::Thread | LoopRole::Latency | LoopRole::Kernel
            )
        })
        .collect();
    for d in &nest.deps {
        if d.is_zero() {
            continue;
        }
        let sp: Vec<i64> = space.iter().map(|&i| d.vector[i]).collect();
        let tp: Vec<i64> = time.iter().map(|&i| d.vector[i]).collect();
        if sp.iter().any(|&c| c.abs() > 1) {
            return false; // non-neighbour space hop
        }
        let moves_in_space = sp.iter().any(|&c| c != 0);
        if moves_in_space || !tp.iter().all(|&c| c == 0) {
            // value crosses cores or time: must advance in time
            if !lex_positive(&tp) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::DepKind;
    use crate::polyhedral::domain::{IterationDomain, LoopDim};

    #[test]
    fn lex_checks() {
        assert!(lex_positive(&[0, 1, -5]));
        assert!(!lex_positive(&[0, -1, 5]));
        assert!(!lex_positive(&[0, 0, 0]));
        assert!(lex_nonnegative(&[0, 0, 0]));
        assert!(!lex_nonnegative(&[-1, 2]));
    }

    #[test]
    fn legal_order_mm() {
        let deps = vec![
            Dependence::new("A", DepKind::Read, vec![0, 1, 0]),
            Dependence::new("B", DepKind::Read, vec![1, 0, 0]),
            Dependence::new("C", DepKind::Flow, vec![0, 0, 1]),
        ];
        assert!(is_legal_order(&deps));
        let bad = vec![Dependence::new("X", DepKind::Flow, vec![0, -1, 0])];
        assert!(!is_legal_order(&bad));
    }

    fn spacetime_nest(roles: Vec<LoopRole>, deps: Vec<Vec<i64>>) -> LoopNest {
        let rank = roles.len();
        let dims = (0..rank).map(|i| LoopDim::new(format!("l{i}"), 8)).collect();
        let deps = deps
            .into_iter()
            .map(|v| Dependence::new("X", DepKind::Flow, v))
            .collect();
        let mut nest = LoopNest::new(IterationDomain::new(dims), deps);
        nest.roles = roles;
        nest
    }

    #[test]
    fn mm_spacetime_is_legal() {
        use LoopRole::{Space, Time};
        // space (i, j), time k; deps (0,1,0) must advance in time? No —
        // the A read dep moves one hop in j and zero in time... in the
        // systolic design A is forwarded j→j+1 while k advances, i.e. the
        // transfer dep as *realised* is (0,1,+1 in time pipeline). The
        // builder realises read deps with a one-cycle forward, so the
        // nest-level check treats pure-space read moves as legal:
        let nest = spacetime_nest(
            vec![Space, Space, Time],
            vec![vec![0, 1, 0], vec![1, 0, 0], vec![0, 0, 1]],
        );
        // (0,1,0): moves in space, time proj (0) — not lex positive ⇒ the
        // raw check fails; with the forwarding realisation (see
        // graph::builder) read deps get a unit time step:
        assert!(!is_legal_spacetime(&nest));
        let realised = spacetime_nest(
            vec![Space, Space, Time],
            vec![vec![0, 1, 1], vec![1, 0, 1], vec![0, 0, 1]],
        );
        assert!(is_legal_spacetime(&realised));
    }

    #[test]
    fn mapping_check_grandfathers_sequential_legality_and_adds_neighbour_transfers() {
        use DepKind::{Flow, Read};
        let d = |k, v: Vec<i64>| Dependence::new("X", k, v);
        // clause 1: anything lex-nonnegative stays legal (MM k-chaining)
        assert!(is_legal_mapping(&[d(Flow, vec![1, 0, -3])], 1));
        // clause 2: stencil halo — space −1, time advances strictly
        assert!(is_legal_mapping(&[d(Flow, vec![-1, 1, 0])], 1));
        // flow that moves in space with no time advance is unrealisable
        assert!(!is_legal_mapping(&[d(Flow, vec![-1, 0, 0])], 1));
        // …but a *read* forward is (the builder adds the unit step)
        assert!(is_legal_mapping(&[d(Read, vec![-1, 0, 0])], 1));
        // far hops stay illegal regardless of time
        assert!(!is_legal_mapping(&[d(Flow, vec![-2, 1, 0])], 1));
        // time regression with zero space is illegal for every kind
        assert!(!is_legal_mapping(&[d(Read, vec![0, -1, 0])], 1));
        assert!(!is_legal_mapping(&[d(Flow, vec![0, 0, -1])], 2));
    }

    #[test]
    fn far_hop_is_illegal() {
        use LoopRole::{Space, Time};
        let nest = spacetime_nest(vec![Space, Time], vec![vec![2, 1]]);
        assert!(!is_legal_spacetime(&nest));
    }

    #[test]
    fn time_regression_is_illegal() {
        use LoopRole::{Space, Time};
        let nest = spacetime_nest(vec![Space, Time], vec![vec![1, -1]]);
        assert!(!is_legal_spacetime(&nest));
    }

    #[test]
    fn zero_dep_is_always_legal() {
        use LoopRole::{Space, Time};
        let nest = spacetime_nest(vec![Space, Time], vec![vec![0, 0]]);
        assert!(is_legal_spacetime(&nest));
    }
}
