//! Loop-nest transformations with exact dependence-vector updates.
//!
//! The mapping engine only ever needs three primitive transforms for this
//! class of programs (paper §III-B): **permutation** (reordering bands),
//! **strip-mine tiling** (splitting one loop into tile × point loops) and
//! **skewing** (for wavefront schedules of recurrences whose space
//! components would otherwise be negative). Each updates the dependence
//! vectors exactly; tiling conservatively *expands* one dependence into
//! the set of (tile, point) component pairs that can occur, so legality
//! checked afterwards is sound.

use super::dependence::Dependence;
use super::domain::LoopDim;
use super::schedule::{LoopNest, LoopRole};
use crate::util::math::ceil_div;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Transform {
    /// Reorder loops: `order[new_pos] = old_pos` (a permutation).
    Permute(Vec<usize>),
    /// Strip-mine loop `dim` by `factor`: tile loop stays at `dim`, the
    /// point loop is inserted at `dim + 1`.
    Tile { dim: usize, factor: u64 },
    /// Skew loop `target` by `factor ×` loop `source` (wavefront).
    Skew {
        target: usize,
        source: usize,
        factor: i64,
    },
}

impl Transform {
    pub fn apply(&self, nest: &LoopNest) -> LoopNest {
        match self {
            Transform::Permute(order) => permute(nest, order),
            Transform::Tile { dim, factor } => tile(nest, *dim, *factor),
            Transform::Skew {
                target,
                source,
                factor,
            } => skew(nest, *target, *source, *factor),
        }
    }
}

/// Apply a sequence of transforms left to right.
pub fn apply_all(nest: &LoopNest, ts: &[Transform]) -> LoopNest {
    ts.iter().fold(nest.clone(), |n, t| t.apply(&n))
}

fn permute(nest: &LoopNest, order: &[usize]) -> LoopNest {
    let rank = nest.rank();
    assert_eq!(order.len(), rank, "permutation must cover all loops");
    let mut seen = vec![false; rank];
    for &o in order {
        assert!(o < rank && !seen[o], "invalid permutation {order:?}");
        seen[o] = true;
    }
    let dims = order
        .iter()
        .map(|&o| nest.domain.dims[o].clone())
        .collect();
    let roles = order.iter().map(|&o| nest.roles[o]).collect();
    let deps = nest
        .deps
        .iter()
        .map(|d| {
            let v = order.iter().map(|&o| d.vector[o]).collect();
            Dependence::new(d.array.clone(), d.kind, v)
        })
        .collect();
    LoopNest {
        domain: super::domain::IterationDomain::new(dims),
        deps,
        roles,
    }
}

fn tile(nest: &LoopNest, dim: usize, factor: u64) -> LoopNest {
    let rank = nest.rank();
    assert!(dim < rank);
    assert!(factor >= 1);
    let old = &nest.domain.dims[dim];
    let tile_extent = ceil_div(old.extent, factor);

    let mut dims = nest.domain.dims.clone();
    dims[dim] = LoopDim::new(format!("{}t", old.name), tile_extent);
    dims.insert(dim + 1, LoopDim::new(format!("{}p", old.name), factor));

    let mut roles = nest.roles.clone();
    let role = roles[dim];
    roles.insert(dim + 1, role);

    // Expand each dependence: component d on `dim` splits into the set of
    // (tile, point) pairs that can realise it. For |d| < factor these are
    // (0, d) — same tile — and (sign, d − sign·factor) — crossing a tile
    // boundary. d == 0 stays (0, 0); |d| == factor becomes exactly
    // (sign, 0).
    let mut deps = Vec::new();
    for d in &nest.deps {
        let c = d.vector[dim];
        let mut splits: Vec<(i64, i64)> = Vec::new();
        if c == 0 {
            splits.push((0, 0));
        } else {
            let sign = c.signum();
            let mag = c.abs() as u64;
            assert!(
                mag <= factor,
                "dependence distance {} exceeds tile factor {} on loop {}",
                mag,
                factor,
                nest.domain.dims[dim].name
            );
            if mag < factor {
                splits.push((0, c));
            }
            splits.push((sign, c - sign * factor as i64));
        }
        for (t, p) in splits {
            let mut v = d.vector.clone();
            v[dim] = t;
            v.insert(dim + 1, p);
            deps.push(Dependence::new(d.array.clone(), d.kind, v));
        }
    }

    LoopNest {
        domain: super::domain::IterationDomain::new(dims),
        deps,
        roles,
    }
}

fn skew(nest: &LoopNest, target: usize, source: usize, factor: i64) -> LoopNest {
    assert_ne!(target, source);
    let rank = nest.rank();
    assert!(target < rank && source < rank);
    // Domain of the skewed loop grows (conservative rectangular hull).
    let mut dims = nest.domain.dims.clone();
    let grow = (factor.unsigned_abs()) * (dims[source].extent.saturating_sub(1));
    dims[target] = LoopDim::new(
        format!("{}s", dims[target].name),
        dims[target].extent + grow,
    );
    let deps = nest
        .deps
        .iter()
        .map(|d| {
            let mut v = d.vector.clone();
            v[target] += factor * v[source];
            Dependence::new(d.array.clone(), d.kind, v)
        })
        .collect();
    LoopNest {
        domain: super::domain::IterationDomain::new(dims),
        deps,
        roles: nest.roles.clone(),
    }
}

/// Convenience: strip-mine `dim` and push the point loop to the innermost
/// position (the latency-hiding move of §III-B-3).
pub fn tile_and_sink(nest: &LoopNest, dim: usize, factor: u64, role: LoopRole) -> LoopNest {
    let tiled = tile(nest, dim, factor);
    let rank = tiled.rank();
    // Move loop dim+1 (the point loop) to the end.
    let mut order: Vec<usize> = (0..rank).filter(|&i| i != dim + 1).collect();
    order.push(dim + 1);
    let mut out = permute(&tiled, &order);
    let last = out.rank() - 1;
    out.roles[last] = role;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::DepKind;
    use crate::polyhedral::domain::IterationDomain;

    fn nest() -> LoopNest {
        LoopNest::new(
            IterationDomain::new(vec![
                LoopDim::new("i", 16),
                LoopDim::new("j", 16),
                LoopDim::new("k", 16),
            ]),
            vec![
                Dependence::new("A", DepKind::Read, vec![0, 1, 0]),
                Dependence::new("C", DepKind::Flow, vec![0, 0, 1]),
            ],
        )
    }

    #[test]
    fn permute_moves_deps_with_loops() {
        let n = nest();
        let p = Transform::Permute(vec![2, 0, 1]).apply(&n);
        assert_eq!(p.domain.dims[0].name, "k");
        assert_eq!(p.deps[0].vector, vec![0, 0, 1]); // A dep followed j
        assert_eq!(p.deps[1].vector, vec![1, 0, 0]); // C dep followed k
    }

    #[test]
    #[should_panic(expected = "invalid permutation")]
    fn permute_rejects_duplicates() {
        Transform::Permute(vec![0, 0, 1]).apply(&nest());
    }

    #[test]
    fn tile_splits_extent_and_expands_deps() {
        let n = nest();
        let t = Transform::Tile { dim: 2, factor: 4 }.apply(&n);
        assert_eq!(t.rank(), 4);
        assert_eq!(t.domain.dims[2].extent, 4); // kt = 16/4
        assert_eq!(t.domain.dims[3].extent, 4); // kp
        // A dep (0,1,0) -> single (0,1,0,0)
        let a: Vec<_> = t.deps.iter().filter(|d| d.array == "A").collect();
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].vector, vec![0, 1, 0, 0]);
        // C dep (0,0,1) -> {(0,0,0,1), (0,0,1,1-4)}
        let c: Vec<_> = t.deps.iter().filter(|d| d.array == "C").collect();
        assert_eq!(c.len(), 2);
        assert!(c.iter().any(|d| d.vector == vec![0, 0, 0, 1]));
        assert!(c.iter().any(|d| d.vector == vec![0, 0, 1, -3]));
    }

    #[test]
    fn tile_exact_multiple_dep() {
        // dep distance == factor → exactly (sign, 0)
        let n = LoopNest::new(
            IterationDomain::new(vec![LoopDim::new("i", 8)]),
            vec![Dependence::new("X", DepKind::Flow, vec![2])],
        );
        let t = Transform::Tile { dim: 0, factor: 2 }.apply(&n);
        assert_eq!(t.deps.len(), 1);
        assert_eq!(t.deps[0].vector, vec![1, 0]);
    }

    #[test]
    fn tile_preserves_cardinality_when_divisible() {
        let n = nest();
        let t = Transform::Tile { dim: 0, factor: 4 }.apply(&n);
        assert_eq!(t.cardinality(), n.cardinality());
    }

    #[test]
    fn skew_makes_wavefront_legal() {
        // dep (1, -1) is lex-negative on loop 1 after loop 0 fixed... skew
        // j by +1·i turns (1,-1) into (1, 0).
        let n = LoopNest::new(
            IterationDomain::new(vec![LoopDim::new("i", 4), LoopDim::new("j", 4)]),
            vec![Dependence::new("X", DepKind::Flow, vec![1, -1])],
        );
        let s = Transform::Skew {
            target: 1,
            source: 0,
            factor: 1,
        }
        .apply(&n);
        assert_eq!(s.deps[0].vector, vec![1, 0]);
        assert_eq!(s.domain.dims[1].extent, 4 + 3); // rectangular hull grows
    }

    #[test]
    fn tile_and_sink_moves_point_loop_innermost() {
        let n = nest();
        let t = tile_and_sink(&n, 0, 4, LoopRole::Latency);
        assert_eq!(t.rank(), 4);
        assert_eq!(t.domain.dims[3].name, "ip");
        assert_eq!(t.roles[3], LoopRole::Latency);
    }
}
