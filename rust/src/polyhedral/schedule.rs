//! Loop nests with schedule roles — the working representation the
//! mapping engine transforms.
//!
//! A [`LoopNest`] couples a rectangular [`IterationDomain`] with the
//! current dependence vectors (kept aligned with the loop order) and a
//! per-loop [`LoopRole`] assignment. Space-time transformation, array
//! partitioning, latency hiding and multiple threading (paper §III-B) are
//! all compositions of [`super::transform::Transform`]s over this type.

use super::dependence::Dependence;
use super::domain::{IterationDomain, LoopDim};
use std::fmt;

/// The schedule role a loop ends up with after mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopRole {
    /// Not yet assigned (fresh nest).
    Unassigned,
    /// Space loop — mapped to a physical array dimension (§III-B-1).
    Space,
    /// Array-partition loop — outer tile over space (§III-B-2).
    Partition,
    /// Time loop — sequential on the array.
    Time,
    /// Latency-hiding point loop — innermost, no carried dependence
    /// (§III-B-3).
    Latency,
    /// Multiple-threading loop — parallel time iterations unrolled across
    /// AIEs (§III-B-4).
    Thread,
    /// Core-kernel loop — inside the AIE kernel scope (§III-A).
    Kernel,
}

impl fmt::Display for LoopRole {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            LoopRole::Unassigned => "unassigned",
            LoopRole::Space => "space",
            LoopRole::Partition => "partition",
            LoopRole::Time => "time",
            LoopRole::Latency => "latency",
            LoopRole::Thread => "thread",
            LoopRole::Kernel => "kernel",
        };
        write!(f, "{s}")
    }
}

/// A loop nest under transformation: domain + aligned dependences + roles.
#[derive(Debug, Clone, PartialEq)]
pub struct LoopNest {
    pub domain: IterationDomain,
    pub deps: Vec<Dependence>,
    pub roles: Vec<LoopRole>,
}

impl LoopNest {
    pub fn new(domain: IterationDomain, deps: Vec<Dependence>) -> Self {
        let rank = domain.rank();
        for d in &deps {
            assert_eq!(d.rank(), rank, "dependence rank must match domain rank");
        }
        Self {
            domain,
            deps,
            roles: vec![LoopRole::Unassigned; rank],
        }
    }

    pub fn rank(&self) -> usize {
        self.domain.rank()
    }

    pub fn dim(&self, i: usize) -> &LoopDim {
        &self.domain.dims[i]
    }

    pub fn dim_index(&self, name: &str) -> Option<usize> {
        self.domain.dim_index(name)
    }

    /// Indices of loops with a given role, outermost first.
    pub fn loops_with_role(&self, role: LoopRole) -> Vec<usize> {
        (0..self.rank()).filter(|&i| self.roles[i] == role).collect()
    }

    /// A loop is parallel iff no dependence has a non-zero component on it
    /// (every carried value flows elsewhere).
    pub fn is_parallel(&self, dim: usize) -> bool {
        self.deps.iter().all(|d| d.vector[dim] == 0)
    }

    /// Dependence distance bound on a loop: max |component| across deps.
    pub fn max_dep_distance(&self, dim: usize) -> i64 {
        self.deps
            .iter()
            .map(|d| d.vector[dim].abs())
            .max()
            .unwrap_or(0)
    }

    /// Total MAC-carrying iterations (domain cardinality).
    pub fn cardinality(&self) -> u64 {
        self.domain.cardinality()
    }
}

impl fmt::Display for LoopNest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.domain)?;
        for (i, r) in self.roles.iter().enumerate() {
            writeln!(
                f,
                "  {}: extent {:6}  role {}",
                self.domain.dims[i].name, self.domain.dims[i].extent, r
            )?;
        }
        for d in &self.deps {
            writeln!(f, "  dep {d}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::DepKind;

    fn mm_nest() -> LoopNest {
        let domain = IterationDomain::new(vec![
            LoopDim::new("i", 8),
            LoopDim::new("j", 8),
            LoopDim::new("k", 8),
        ]);
        let deps = vec![
            Dependence::new("A", DepKind::Read, vec![0, 1, 0]),
            Dependence::new("B", DepKind::Read, vec![1, 0, 0]),
            Dependence::new("C", DepKind::Flow, vec![0, 0, 1]),
        ];
        LoopNest::new(domain, deps)
    }

    #[test]
    fn parallel_loop_detection() {
        let nest = mm_nest();
        // In MM no loop is fully parallel w.r.t. all three arrays' deps:
        assert!(!nest.is_parallel(0));
        assert!(!nest.is_parallel(1));
        assert!(!nest.is_parallel(2));
        // But considering only the flow dep (C), i and j are parallel:
        let flow_only = LoopNest::new(nest.domain.clone(), vec![nest.deps[2].clone()]);
        assert!(flow_only.is_parallel(0));
        assert!(flow_only.is_parallel(1));
        assert!(!flow_only.is_parallel(2));
    }

    #[test]
    fn dep_distance_bounds() {
        let nest = mm_nest();
        assert_eq!(nest.max_dep_distance(0), 1);
        assert_eq!(nest.max_dep_distance(2), 1);
    }

    #[test]
    fn role_queries() {
        let mut nest = mm_nest();
        nest.roles[0] = LoopRole::Space;
        nest.roles[1] = LoopRole::Space;
        nest.roles[2] = LoopRole::Time;
        assert_eq!(nest.loops_with_role(LoopRole::Space), vec![0, 1]);
        assert_eq!(nest.loops_with_role(LoopRole::Time), vec![2]);
    }

    #[test]
    #[should_panic(expected = "rank")]
    fn mismatched_dep_rank_panics() {
        let domain = IterationDomain::new(vec![LoopDim::new("i", 4)]);
        LoopNest::new(
            domain,
            vec![Dependence::new("A", DepKind::Read, vec![0, 1])],
        );
    }
}
