//! Polyhedral substrate for uniform recurrences (DESIGN.md §7).
//!
//! Uniform recurrences (Karp–Miller–Winograd) have *constant* dependence
//! vectors, which lets this layer be exact without an ISL dependency:
//! iteration domains are rectangular after loop normalisation
//! ([`domain`]), accesses are affine maps with unit linear parts
//! ([`affine`]), dependences are integer vectors ([`dependence`]), and
//! schedules are compositions of permutation / tiling / skewing band
//! transforms ([`transform`]) whose effect on dependence vectors is
//! computed exactly, so legality ([`legality`]) is a lexicographic check
//! on the transformed vectors — the same criterion AutoSA/PolySA apply
//! through ISL.

pub mod affine;
pub mod dependence;
pub mod domain;
pub mod legality;
pub mod schedule;
pub mod transform;

pub use affine::{AffineExpr, AffineMap};
pub use dependence::{DepKind, Dependence};
pub use domain::{IterationDomain, LoopDim};
pub use legality::{is_legal_order, lex_positive};
pub use schedule::{LoopNest, LoopRole};
pub use transform::Transform;
