//! Polyhedral substrate for uniform recurrences (DESIGN.md §7).
//!
//! Uniform recurrences (Karp–Miller–Winograd) have *constant* dependence
//! vectors, which lets this layer be exact without an ISL dependency:
//! iteration domains are rectangular after loop normalisation
//! ([`domain`]), accesses are affine maps with unit linear parts
//! ([`affine`]), dependences are integer vectors ([`dependence`]), and
//! schedules are compositions of permutation / tiling / skewing band
//! transforms ([`transform`]) whose effect on dependence vectors is
//! computed exactly, so legality ([`legality`]) is a lexicographic check
//! on the transformed vectors — the same criterion AutoSA/PolySA apply
//! through ISL.
//!
//! This layer underpins the paper's §III-B space-time transformation:
//! [`transform::Transform`] supplies the permute/tile/skew band
//! transforms the mapper composes, and
//! [`legality::is_legal_spacetime`] enforces the systolic realisability
//! conditions (neighbour-only space hops, strictly advancing time) of
//! §III-B-1.

pub mod affine;
pub mod dependence;
pub mod domain;
pub mod legality;
pub mod schedule;
pub mod transform;

pub use affine::{AffineExpr, AffineMap};
pub use dependence::{DepKind, Dependence};
pub use domain::{IterationDomain, LoopDim};
pub use legality::{is_legal_mapping, is_legal_order, lex_nonnegative, lex_positive};
pub use schedule::{LoopNest, LoopRole};
pub use transform::Transform;
