//! Uniform dependences and their extraction from array accesses.
//!
//! For a uniform recurrence every dependence is a constant integer vector
//! `d` meaning iteration `I` depends on iteration `I − d`. Following
//! AutoSA (paper §III-C-1) dependences are classified as:
//!
//! * **Read** — the same read-only datum is used at iterations that
//!   differ by `d` (reuse direction for input propagation),
//! * **Flow** — a value written at `I − d` is read at `I` (true systolic
//!   forwarding / accumulation chains),
//! * **Output** — the same location is written at `I − d` and `I`
//!   (reduction chains; the last write wins).

use super::affine::AffineMap;
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DepKind {
    Read,
    Flow,
    Output,
}

impl fmt::Display for DepKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DepKind::Read => write!(f, "read"),
            DepKind::Flow => write!(f, "flow"),
            DepKind::Output => write!(f, "output"),
        }
    }
}

/// A uniform dependence: `iteration I depends on I − vector`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Dependence {
    pub array: String,
    pub kind: DepKind,
    pub vector: Vec<i64>,
}

impl Dependence {
    pub fn new(array: impl Into<String>, kind: DepKind, vector: Vec<i64>) -> Self {
        Self {
            array: array.into(),
            kind,
            vector,
        }
    }

    pub fn rank(&self) -> usize {
        self.vector.len()
    }

    pub fn is_zero(&self) -> bool {
        self.vector.iter().all(|&c| c == 0)
    }
}

impl fmt::Display for Dependence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}] {:?}", self.array, self.kind, self.vector)
    }
}

/// Derive the *reuse* dependence vectors of a read access: the basis
/// directions of the access map's null space — iterations mapping to the
/// same element. Exact for the unit-coefficient selection maps used by
/// uniform recurrences: a loop dim not referenced by the access is a
/// reuse direction.
pub fn reuse_directions(access: &AffineMap, rank: usize) -> Vec<Vec<i64>> {
    let mut out = Vec::new();
    for d in 0..rank {
        let referenced = access.exprs.iter().any(|e| e.coeffs[d] != 0);
        if !referenced {
            let mut v = vec![0; rank];
            v[d] = 1;
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::affine::AffineMap;

    #[test]
    fn mm_reuse_directions() {
        // MM over (i, j, k): A[i,k] reused along j; B[k,j] along i; C[i,j] along k.
        let a = AffineMap::select(&[0, 2], &[0, 0], 3);
        let b = AffineMap::select(&[2, 1], &[0, 0], 3);
        let c = AffineMap::select(&[0, 1], &[0, 0], 3);
        assert_eq!(reuse_directions(&a, 3), vec![vec![0, 1, 0]]);
        assert_eq!(reuse_directions(&b, 3), vec![vec![1, 0, 0]]);
        assert_eq!(reuse_directions(&c, 3), vec![vec![0, 0, 1]]);
    }

    #[test]
    fn fully_referenced_access_has_no_reuse() {
        let m = AffineMap::identity(3);
        assert!(reuse_directions(&m, 3).is_empty());
    }

    #[test]
    fn zero_dep_detection() {
        assert!(Dependence::new("A", DepKind::Read, vec![0, 0]).is_zero());
        assert!(!Dependence::new("A", DepKind::Flow, vec![0, 1]).is_zero());
    }
}
