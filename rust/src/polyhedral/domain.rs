//! Rectangular iteration domains.
//!
//! After loop normalisation every uniform recurrence in scope iterates a
//! product of half-open intervals `[0, extent)`; tiling and permutation
//! keep the domain rectangular, which is what makes the exact dependence
//! arithmetic in [`super::transform`] possible.

use std::fmt;

/// One loop dimension: a named, normalised `[0, extent)` iterator.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LoopDim {
    pub name: String,
    pub extent: u64,
}

impl LoopDim {
    pub fn new(name: impl Into<String>, extent: u64) -> Self {
        Self {
            name: name.into(),
            extent,
        }
    }
}

/// A product of normalised loop dimensions, outermost first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IterationDomain {
    pub dims: Vec<LoopDim>,
}

impl IterationDomain {
    pub fn new(dims: Vec<LoopDim>) -> Self {
        Self { dims }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total number of iteration points (saturating).
    pub fn cardinality(&self) -> u64 {
        self.dims
            .iter()
            .map(|d| d.extent)
            .fold(1u64, |a, b| a.saturating_mul(b))
    }

    pub fn extents(&self) -> Vec<u64> {
        self.dims.iter().map(|d| d.extent).collect()
    }

    pub fn contains(&self, point: &[i64]) -> bool {
        point.len() == self.rank()
            && point
                .iter()
                .zip(&self.dims)
                .all(|(&p, d)| p >= 0 && (p as u64) < d.extent)
    }

    /// Iterate all points (only for small domains — used by tests and the
    /// functional executor's schedule walker).
    pub fn points(&self) -> DomainPoints<'_> {
        DomainPoints {
            domain: self,
            current: vec![0; self.rank()],
            done: self.cardinality() == 0,
        }
    }

    pub fn dim_index(&self, name: &str) -> Option<usize> {
        self.dims.iter().position(|d| d.name == name)
    }
}

impl fmt::Display for IterationDomain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{ ")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, " × ")?;
            }
            write!(f, "{}:[0,{})", d.name, d.extent)?;
        }
        write!(f, " }}")
    }
}

/// Row-major point iterator over a rectangular domain.
pub struct DomainPoints<'a> {
    domain: &'a IterationDomain,
    current: Vec<i64>,
    done: bool,
}

impl Iterator for DomainPoints<'_> {
    type Item = Vec<i64>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        let out = self.current.clone();
        // increment innermost-first
        for i in (0..self.current.len()).rev() {
            self.current[i] += 1;
            if (self.current[i] as u64) < self.domain.dims[i].extent {
                return Some(out);
            }
            self.current[i] = 0;
        }
        self.done = true;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d3() -> IterationDomain {
        IterationDomain::new(vec![
            LoopDim::new("i", 2),
            LoopDim::new("j", 3),
            LoopDim::new("k", 4),
        ])
    }

    #[test]
    fn cardinality_and_contains() {
        let d = d3();
        assert_eq!(d.cardinality(), 24);
        assert!(d.contains(&[1, 2, 3]));
        assert!(!d.contains(&[2, 0, 0]));
        assert!(!d.contains(&[0, -1, 0]));
        assert!(!d.contains(&[0, 0]));
    }

    #[test]
    fn points_enumerates_all_exactly_once() {
        let d = d3();
        let pts: Vec<_> = d.points().collect();
        assert_eq!(pts.len(), 24);
        let mut uniq = pts.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 24);
        assert_eq!(pts[0], vec![0, 0, 0]);
        assert_eq!(pts[1], vec![0, 0, 1]); // innermost fastest
        assert!(pts.iter().all(|p| d.contains(p)));
    }

    #[test]
    fn empty_domain_has_no_points() {
        let d = IterationDomain::new(vec![LoopDim::new("i", 0)]);
        assert_eq!(d.points().count(), 0);
    }

    #[test]
    fn dim_lookup() {
        let d = d3();
        assert_eq!(d.dim_index("j"), Some(1));
        assert_eq!(d.dim_index("z"), None);
    }
}
