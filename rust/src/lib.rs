//! # WideSA — high array-utilization mapping of uniform recurrences on ACAP
//!
//! Reproduction of *WideSA: A High Array Utilization Mapping Scheme for
//! Uniform Recurrences on the Versal ACAP Architecture* (Dai, Shi, Luo —
//! CS.AR 2024) as a three-layer rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the WideSA framework: a polyhedral mapping
//!   engine that derives systolic-array schedules for uniform recurrences
//!   ([`mapping`]), a mapped-graph builder with packet-switch/broadcast
//!   port reduction ([`graph`]), the routing-aware PLIO assignment of the
//!   paper's Algorithm 1 ([`plio`]), a constraint-guided place-and-route
//!   substrate standing in for the Vitis AIE compiler ([`place_route`]),
//!   a cycle-approximate simulator of the VCK5000 board ([`sim`]),
//!   heterogeneous-backend code generators ([`codegen`]), the baselines
//!   the paper compares against ([`baselines`]), and the evaluation
//!   harness that regenerates every table and figure ([`eval`]).
//! * **L2/L1 (python/, build-time only)** — the recurrences' compute as
//!   JAX graphs calling Pallas tile kernels, AOT-lowered to HLO text.
//! * **Runtime bridge** — [`runtime`] loads the AOT artifacts through the
//!   PJRT C API (`xla` crate) so mapped designs can be *functionally*
//!   replayed tile-by-tile from rust ([`coordinator`]); python never runs
//!   on the request path.
//!
//! Quickstart: see `examples/quickstart.rs`, or
//! `cargo run --release -- table3` to regenerate the paper's Table III.

pub mod arch;
pub mod baselines;
pub mod codegen;
pub mod coordinator;
pub mod eval;
pub mod graph;
pub mod mapping;
pub mod place_route;
pub mod plio;
pub mod polyhedral;
pub mod recurrence;
pub mod runtime;
pub mod sim;
pub mod util;

pub use coordinator::framework::{WideSa, WideSaConfig};
pub use recurrence::{dtype::DType, library, spec::UniformRecurrence};
