//! # WideSA — high array-utilization mapping of uniform recurrences on ACAP
//!
//! Reproduction of *WideSA: A High Array Utilization Mapping Scheme for
//! Uniform Recurrences on the Versal ACAP Architecture* (Dai, Shi, Luo —
//! CS.AR 2024, arXiv:2401.16792) as a three-layer rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the WideSA framework: a polyhedral mapping
//!   engine that derives systolic-array schedules for uniform recurrences
//!   ([`mapping`], paper §III-B), a mapped-graph builder with
//!   packet-switch/broadcast port reduction ([`graph`], §III-C-1), the
//!   routing-aware PLIO assignment of the paper's Algorithm 1 ([`plio`],
//!   §III-C-2), a constraint-guided place-and-route substrate standing in
//!   for the Vitis AIE compiler ([`place_route`], §II-A-2/§III-C), a
//!   cycle-approximate simulator of the VCK5000 board ([`sim`]),
//!   heterogeneous-backend code generators ([`codegen`], Figure 5), the
//!   baselines the paper compares against ([`baselines`]), the
//!   evaluation harness that regenerates every table and figure
//!   ([`eval`]), and a long-lived compile service with a sharded design
//!   cache, single-flight deduplication and pool-sharded DSE ([`serve`],
//!   the ROADMAP's serving layer), all instrumented end-to-end by a
//!   dependency-free metrics + tracing layer with Chrome-trace export
//!   and per-commit bench trending ([`obs`]).
//! * **L2/L1 (`python/`, build-time only)** — the recurrences' compute as
//!   JAX graphs calling Pallas tile kernels, AOT-lowered to HLO text.
//! * **Runtime bridge** — [`runtime`] functionally replays mapped designs
//!   tile-by-tile from rust ([`coordinator`]); python never runs on the
//!   request path. By default a deterministic in-process stub executor
//!   ([`runtime::stub`]) runs the kernels in host code; enable the
//!   off-by-default `pjrt` cargo feature to execute the real AOT
//!   artifacts through the PJRT C API (`xla` crate).
//!
//! ## Quickstart
//!
//! One call takes a uniform recurrence through demarcation → space-time
//! DSE → mapped graph → PLIO assignment → place & route → simulation →
//! code generation:
//!
//! ```
//! use widesa::{library, DType, DseConstraints, WideSa, WideSaConfig};
//!
//! let ws = WideSa::new(WideSaConfig {
//!     constraints: DseConstraints {
//!         max_aies: Some(64), // small budget keeps the doctest fast
//!         ..Default::default()
//!     },
//!     ..Default::default()
//! });
//! let design = ws.compile(&library::mm(1024, 1024, 1024, DType::F32)).unwrap();
//! assert!(design.compile.success, "place & route must succeed");
//! assert!(design.estimate.perf.tops > 0.0);
//! assert!(design.estimate.perf.aies <= 64);
//! assert!(design.estimate.power.watts > 0.0); // every estimate carries power
//! println!("{}", design.report());
//! ```
//!
//! See `examples/quickstart.rs`, or `cargo run --release -- table3` to
//! regenerate the paper's Table III.
//!
//! For repeated mappings, wrap the framework in the compile service —
//! [`ServeHandle`] caches designs by canonical key and deduplicates
//! concurrent identical requests; `widesa serve --stdin` exposes the
//! same thing as a JSON-lines process (see [`serve`]).
//!
//! The DSE ranks candidates on **exact merged-PLIO port counts**
//! ([`PortModel::Exact`], via the incremental predictor in
//! [`graph::packet`]) — the same counts packet merging realises and the
//! simulator prices — so one consistent port model runs end to end; see
//! the README's cost-model section.

pub mod arch;
pub mod baselines;
pub mod codegen;
pub mod coordinator;
pub mod eval;
pub mod graph;
pub mod mapping;
pub mod obs;
pub mod place_route;
pub mod plio;
pub mod polyhedral;
pub mod recurrence;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

pub use coordinator::framework::{
    CompiledDesign, FrontierSummary, NoLegalMapping, WideSa, WideSaConfig,
};
pub use mapping::cost::{Estimate, PortModel};
pub use mapping::dse::{DseConstraints, Objective};
pub use recurrence::{dtype::DType, library, spec::UniformRecurrence};
pub use serve::{CacheOutcome, Overloaded, ServeConfig, ServeHandle, ServeResult, ServeStats};
