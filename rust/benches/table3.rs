//! Bench: regenerate Table III (E1) — times the full WideSA pipeline per
//! benchmark row, then prints the reproduced table. The timing is the
//! framework's own compile cost (mapping → P&R → sim), the quantity the
//! paper's "extended compilation time" challenge is about.

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::eval::table3;
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};
use widesa::util::bench::bench;

fn main() {
    println!("== bench table3: WideSA pipeline cost per benchmark row ==");
    let rows: Vec<(&str, _, u64)> = vec![
        ("MM f32 8192^3", library::mm(8192, 8192, 8192, DType::F32), 400),
        ("MM i8 10240^3", library::mm(10240, 10240, 10240, DType::I8), 400),
        ("Conv2D i8 10240^2 8x8", library::conv2d(10240, 10240, 8, 8, DType::I8), 400),
        ("FFT2D cf32 8192^2", library::fft2d(8192, 8192, DType::CF32), 320),
        ("FIR f32 1M x 15", library::fir(1048576, 15, DType::F32), 256),
    ];
    for (name, rec, cap) in rows {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(cap),
                ..Default::default()
            },
            ..Default::default()
        });
        bench(&format!("pipeline/{name}"), 5, || {
            let d = ws.compile(&rec).unwrap();
            std::hint::black_box(d.estimate.perf.tops);
        });
    }

    println!("\n== regenerated Table III ==");
    let (_, table) = table3::run();
    println!("{table}");
}
