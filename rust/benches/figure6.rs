//! Bench: regenerate Figure 6 (E3) — the scalability sweeps, timing one
//! sweep point and the full figure.

use widesa::eval::figure6;
use widesa::util::bench::bench;

fn main() {
    println!("== bench figure6: sweep cost ==");
    bench("figure6/aie-plio-sweep (32 points)", 3, || {
        std::hint::black_box(figure6::sweep_aies_plios().len());
    });
    bench("figure6/buffer-sweep (3 points)", 3, || {
        std::hint::black_box(figure6::sweep_buffers().len());
    });

    println!("\n== regenerated Figure 6 series ==");
    let (_, _, rendered) = figure6::run();
    println!("{rendered}");
}
