//! Bench: the cost of ranking on the truth.
//!
//! The DSE prices every candidate with exact merged-PLIO port counts
//! (`PortModel::Exact`, via the incremental predictor) instead of the
//! legacy analytic packing. That exactness must stay cheap: this binary
//! *enforces* that scoring a candidate under the exact model costs at
//! most 2× the analytic score on the MM workload, and exits non-zero
//! above the bound. It also reports where the two rankings diverge, so a
//! perf run doubles as an A/B sanity check.
//!
//! Run with `cargo bench --bench bench_rank` (or `make rank-smoke`).

use widesa::arch::vck5000::BoardConfig;
use widesa::graph::builder::build;
use widesa::graph::packet::{merge_ports_with_budget, predict_ports};
use widesa::mapping::cost::{CostModel, PortModel};
use widesa::mapping::dse::{self, explore_all, DseConstraints};
use widesa::recurrence::library;
use widesa::util::bench::bench;
use widesa::DType;

fn main() {
    let board = BoardConfig::vck5000();
    let cons = DseConstraints {
        max_aies: Some(400),
        ..Default::default()
    };
    let rec = library::mm(8192, 8192, 8192, DType::F32);
    let plan = dse::plan(&rec, &board, &cons);
    let n = plan.choices.len().max(1);
    let exact_model = CostModel::new(board.clone());
    let analytic_model = CostModel::new(board.clone()).with_port_model(PortModel::Analytic);

    println!("== rank: exact-port vs analytic candidate scoring (MM, {n} candidates) ==");
    let exact = bench("rank/score-all exact", 300, || {
        for choice in &plan.choices {
            std::hint::black_box(dse::score_choice(&rec, &exact_model, &cons, &plan, choice.clone()));
        }
    });
    let analytic = bench("rank/score-all analytic", 300, || {
        for choice in &plan.choices {
            std::hint::black_box(dse::score_choice(
                &rec,
                &analytic_model,
                &cons,
                &plan,
                choice.clone(),
            ));
        }
    });
    let per_exact = exact.median_s / n as f64;
    let per_analytic = analytic.median_s / n as f64;
    let ratio = per_exact / per_analytic.max(1e-12);
    println!(
        "per-candidate score: exact {:.3} µs vs analytic {:.3} µs → {ratio:.2}× overhead",
        per_exact * 1e6,
        per_analytic * 1e6,
    );

    // A/B divergence report: where does exactness change the ranking?
    let exact_rank = explore_all(&rec, &board, &cons);
    let analytic_rank = explore_all(
        &rec,
        &board,
        &DseConstraints {
            analytic_ranking: true,
            ..cons.clone()
        },
    );
    let diverged = exact_rank
        .iter()
        .zip(&analytic_rank)
        .filter(|(e, a)| e.0.summary() != a.0.summary())
        .count();
    println!("ranking positions where exact and analytic disagree: {diverged}/{}", exact_rank.len());

    // Sanity: the exact winner's predicted ports equal the real merge.
    if let Some((winner, est)) = exact_rank.first() {
        let g = build(winner, &exact_model);
        let (_, stats) = merge_ports_with_budget(&g, exact_model.channel_bw(), 78, 78);
        let predicted = predict_ports(winner, &exact_model, exact_model.channel_bw(), 78, 78);
        assert_eq!(predicted, stats, "predictor diverged from merge on the winner");
        println!(
            "winner: {} ports {}/{} (est {:.3} TOPS)",
            winner.summary(),
            stats.in_ports_after,
            stats.out_ports_after,
            est.perf.tops
        );
    }

    if ratio > 2.0 {
        eprintln!("FAIL: exact-count ranking adds {ratio:.2}× > 2× per-candidate overhead");
        std::process::exit(1);
    }
    println!("\nbench_rank OK (exact ranking ≤ 2× analytic per candidate)");
}
