//! Bench: cold-compile latency through the dense-index P&R hot path —
//! the compile-time half of the paper's claim (§V, Table IV: constraint-
//! guided P&R compiles 400-AIE designs where unconstrained solvers time
//! out) and the serve layer's cold-miss tail-latency driver.
//!
//! Measures cold `WideSa::compile` wall time on MM-400, FIR and a conv
//! point, per-stage place / assign / route latency on the MM-400 merged
//! graph, and annealer iteration throughput on the E5 400-AIE workload —
//! dense vs the retained HashMap baseline (`anneal::legacy`). **Gate:**
//! the dense annealer must be ≥2× the legacy iterations/sec and remain
//! bit-identical per seed, or this binary exits non-zero. Results are
//! written to `BENCH_compile.json` at the repo root so every subsequent
//! PR extends a perf trajectory.
//!
//! Run with `make pnr-smoke` (or
//! `cargo bench --bench bench_compile --features legacy-hash-pnr`).

use std::path::Path;
use widesa::arch::vck5000::BoardConfig;
use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::mapping::dse::DseConstraints;
use widesa::place_route::anneal::{anneal, legacy::anneal_legacy};
use widesa::place_route::placement::place;
use widesa::place_route::router::route_all;
use widesa::plio::assignment::assign;
use widesa::recurrence::library;
use widesa::recurrence::spec::UniformRecurrence;
use widesa::util::bench::bench;
use widesa::util::json::Json;
use widesa::DType;

/// Iteration budget for the annealer throughput measurement (the E5
/// 400-AIE workload does not converge at this scale, so both
/// implementations run the full budget).
const ANNEAL_ITERS: u64 = 200_000;
/// The speedup gate: dense iterations/sec ≥ GATE × legacy.
const GATE: f64 = 2.0;

fn framework(cap: u64) -> WideSa {
    WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        },
        ..Default::default()
    })
}

fn cold_compile_ms(name: &str, rec: &UniformRecurrence, cap: u64) -> f64 {
    let ws = framework(cap);
    let r = bench(&format!("compile/cold/{name}"), 3, || {
        std::hint::black_box(ws.compile(rec).expect("compile").compile.success);
    });
    r.median_s * 1e3
}

fn main() {
    let board = BoardConfig::vck5000();

    println!("== compile: cold end-to-end latency ==");
    let workloads = [
        ("mm-400", library::mm(8192, 8192, 8192, DType::F32), 400u64),
        ("fir-256", library::fir(1048576, 15, DType::F32), 256),
        ("conv-400", library::conv2d(1024, 1024, 4, 4, DType::I16), 400),
    ];
    let cold: Vec<(&str, f64)> = workloads
        .iter()
        .map(|(name, rec, cap)| (*name, cold_compile_ms(name, rec, *cap)))
        .collect();

    println!("== compile: per-stage latency (MM-400 merged graph) ==");
    let d = framework(400)
        .compile(&library::mm(8192, 8192, 8192, DType::F32))
        .expect("MM-400 compile");
    let g = &d.graph;
    let place_ms = bench("compile/stage/place", 50, || {
        std::hint::black_box(place(g, &board.array).is_some());
    })
    .median_s
        * 1e3;
    let pl = place(g, &board.array).expect("placement");
    let assign_ms = bench("compile/stage/assign", 50, || {
        std::hint::black_box(
            assign(g, &pl, &board.plio, board.array.rc_west, board.array.rc_east).feasible,
        );
    })
    .median_s
        * 1e3;
    let a = assign(g, &pl, &board.plio, board.array.rc_west, board.array.rc_east);
    let route_ms = bench("compile/stage/route", 50, || {
        std::hint::black_box(
            route_all(
                g,
                &pl,
                &a.columns,
                board.array.cols,
                board.array.rc_west,
                board.array.rc_east,
            )
            .success,
        );
    })
    .median_s
        * 1e3;

    println!("== anneal: dense vs legacy HashMap (E5 400-AIE workload) ==");
    let dense_r = bench("anneal/dense 200k iters (400 AIEs)", 3, || {
        std::hint::black_box(anneal(g, &board.array, 11, ANNEAL_ITERS).iterations);
    });
    let legacy_r = bench("anneal/legacy 200k iters (400 AIEs)", 3, || {
        std::hint::black_box(anneal_legacy(g, &board.array, 11, ANNEAL_ITERS).iterations);
    });
    // equivalence spot-check doubles as a gate: same seed ⇒ same trace
    let dv = anneal(g, &board.array, 11, ANNEAL_ITERS);
    let lv = anneal_legacy(g, &board.array, 11, ANNEAL_ITERS);
    assert_eq!(
        (dv.iterations, dv.violations),
        (lv.iterations, lv.violations),
        "dense annealer diverged from the legacy baseline"
    );
    let dense_ips = dv.iterations as f64 / dense_r.median_s;
    let legacy_ips = lv.iterations as f64 / legacy_r.median_s;
    let speedup = dense_ips / legacy_ips.max(1e-9);
    println!(
        "anneal throughput: dense {:.0} iters/s vs legacy {:.0} iters/s → {speedup:.2}×",
        dense_ips, legacy_ips
    );

    // BENCH_compile.json at the repo root: the compile-latency trajectory
    let out = Json::obj(vec![
        ("bench", Json::Str("compile".into())),
        (
            "cold_ms",
            Json::obj(cold.iter().map(|(n, ms)| (*n, Json::Num(*ms))).collect()),
        ),
        (
            "stages_ms",
            Json::obj(vec![
                ("place", Json::Num(place_ms)),
                ("assign", Json::Num(assign_ms)),
                ("route", Json::Num(route_ms)),
            ]),
        ),
        (
            "anneal",
            Json::obj(vec![
                ("iters", Json::Num(ANNEAL_ITERS as f64)),
                ("dense_iters_per_sec", Json::Num(dense_ips)),
                ("legacy_iters_per_sec", Json::Num(legacy_ips)),
                ("speedup", Json::Num(speedup)),
            ]),
        ),
        ("gate_speedup_min", Json::Num(GATE)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("BENCH_compile.json");
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_compile.json");
    println!("wrote {}", path.display());

    if speedup < GATE {
        eprintln!(
            "FAIL: dense annealer is only {speedup:.2}× the legacy baseline (gate {GATE}×)"
        );
        std::process::exit(1);
    }
    println!("OK: dense annealer ≥{GATE}× legacy ({speedup:.2}×)");
}
