//! Bench: L3 hot paths (the §Perf targets) — cost-model evaluation, DSE,
//! Algorithm 1, congestion recompute, XY routing, packet merge, the
//! annealer's iteration rate, and the PJRT tile-execution latency the
//! functional replay pays per round.

use widesa::arch::array::AieArray;
use widesa::arch::vck5000::BoardConfig;
use widesa::graph::builder::build;
use widesa::graph::packet::merge_ports;
use widesa::mapping::cost::CostModel;
use widesa::mapping::dse::{explore, explore_all, DseConstraints};
use widesa::place_route::anneal::anneal;
use widesa::place_route::placement::place;
use widesa::place_route::router::route_all;
use widesa::plio::assignment::assign;
use widesa::plio::congestion::congestion;
use widesa::recurrence::{dtype::DType, library};
use widesa::runtime::artifact::Manifest;
use widesa::runtime::client::Runtime;
use widesa::runtime::executor::Tensor;
use widesa::util::bench::bench;
use widesa::util::rng::XorShift64;

fn main() {
    let board = BoardConfig::vck5000();
    let rec = library::mm(8192, 8192, 8192, DType::F32);
    let cons = DseConstraints {
        max_aies: Some(400),
        ..Default::default()
    };
    let (cand, _) = explore(&rec, &board, &cons).unwrap();
    let model = CostModel::new(board.clone());
    let (graph, _) = merge_ports(&build(&cand, &model), model.channel_bw());
    let placement = place(&graph, &AieArray::default()).unwrap();
    let assignment = assign(&graph, &placement, &board.plio, 48, 48);

    println!("== L3 hot paths ==");
    bench("cost-model/estimate", 2000, || {
        std::hint::black_box(model.estimate(&cand).perf.tops);
    });
    bench("dse/explore-all (MM)", 50, || {
        std::hint::black_box(explore_all(&rec, &board, &cons).len());
    });
    bench("graph/build+merge (400 AIEs)", 50, || {
        let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
        std::hint::black_box(g.edges.len());
    });
    bench("plio/algorithm1 (400 AIEs)", 100, || {
        std::hint::black_box(assign(&graph, &placement, &board.plio, 48, 48).feasible);
    });
    bench("plio/congestion-recompute", 200, || {
        std::hint::black_box(
            congestion(&graph, &placement, &assignment.columns, 50).max_east(),
        );
    });
    bench("router/xy-route-all (400 AIEs)", 100, || {
        std::hint::black_box(
            route_all(&graph, &placement, &assignment.columns, 50, 48, 48).total_hops,
        );
    });
    bench("anneal/20k-iterations (400 AIEs)", 5, || {
        std::hint::black_box(anneal(&graph, &AieArray::default(), 9, 20_000).iterations);
    });

    // PJRT replay hot path (needs `make artifacts`)
    if Manifest::default_dir().join("manifest.json").exists() {
        let mut rt = Runtime::new().unwrap();
        let mut rng = XorShift64::new(3);
        let n = 128usize;
        let mut a = vec![0f32; n * n];
        let mut b = vec![0f32; n * n];
        let mut c = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        rng.fill_f32(&mut c);
        let inputs = [
            Tensor::f32(vec![n, n], a),
            Tensor::f32(vec![n, n], b),
            Tensor::f32(vec![n, n], c),
        ];
        rt.run("mm_f32_128", &inputs).unwrap(); // compile outside timing
        println!("\n== PJRT replay hot path ==");
        bench("pjrt/mm_f32_128 tile execute", 20, || {
            std::hint::black_box(rt.run("mm_f32_128", &inputs).unwrap().len());
        });
    } else {
        eprintln!("(skipping PJRT benches: run `make artifacts`)");
    }
}
