//! Bench: open-loop serve load — tail latency and shed rate under a
//! production-shaped arrival process, plus the observability overhead
//! gate.
//!
//! Unlike `bench_serve` (closed-loop microbenchmarks of one key), this
//! drives the full admission → cache → single-flight → cold-compile
//! stack the way a fleet does: requests arrive on a fixed open-loop
//! schedule (arrivals don't wait for completions, so queueing delay is
//! *measured*, not hidden), over a mixed key population — `HOT_FRACTION`
//! of requests draw from `HOT_KEYS` pre-warmed designs, the rest are
//! unique cold keys that must compile under a bounded `max_inflight`.
//!
//! The whole load runs **twice**: once with span recording off (the
//! production default) and once with `obs::trace` recording every span
//! to the sink. The second run answers "what does `--trace-out` cost on
//! the hot path" — gated at ≤ `GATE_OVERHEAD_PCT` on p50 (with a small
//! absolute floor, since 5 % of a ~100 µs cache hit is below timer
//! noise).
//!
//! Reports p50/p99/p999 request latency (measured from scheduled
//! arrival, the open-loop convention) plus the shed rate and the
//! overhead comparison, and writes them to `BENCH_serve.json` at the
//! repo root (the committed seed schema is overwritten by
//! `make serve-load-smoke` in CI).
//!
//! Run with `cargo bench --bench bench_serve_load`.

use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};
use widesa::mapping::dse::DseConstraints;
use widesa::obs::trace::{self, TraceCtx};
use widesa::recurrence::library;
use widesa::serve::{Overloaded, ServeConfig, ServeHandle, ServeStats};
use widesa::util::json::Json;
use widesa::util::rng::XorShift64;
use widesa::{DType, WideSaConfig};

const REQUESTS: usize = 400;
const RATE_RPS: f64 = 400.0;
const HOT_KEYS: usize = 4;
/// Fraction of arrivals that hit the hot key set (the production shape:
/// most traffic re-requests a few Table II-class kernels).
const HOT_FRACTION: f64 = 0.9;
const MAX_INFLIGHT: usize = 2;
/// p50 must stay a hit-latency number, not a compile-latency number: the
/// hot set dominates arrivals, so the median request is a cache probe.
const GATE_P50_US: f64 = 50_000.0;
/// Instrumented p50 may exceed uninstrumented p50 by at most this much…
const GATE_OVERHEAD_PCT: f64 = 5.0;
/// …or this absolute floor, whichever is larger (5 % of a ~100 µs hit is
/// below scheduler/timer noise on shared CI runners).
const GATE_OVERHEAD_FLOOR_US: f64 = 250.0;

/// Request outcome classes recorded per arrival.
const OK: u8 = 0;
const SHED: u8 = 1;
const ERR: u8 = 2;

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted_us.len() - 1) as f64).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

struct LoadReport {
    p50: f64,
    p99: f64,
    p999: f64,
    shed_rate: f64,
    ok: usize,
    shed: usize,
    err: usize,
    stats: ServeStats,
    stage: (f64, f64, f64),
}

/// One full open-loop run on a fresh handle. `instrumented` toggles span
/// recording; everything else (schedule, keys, rates, the per-request
/// `TraceCtx` install that `handle_line` always does) is identical
/// between runs so the delta isolates the recording cost.
fn run_load(instrumented: bool) -> LoadReport {
    trace::set_enabled(instrumented);
    let handle = ServeHandle::new(ServeConfig {
        base: WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(32), // small budget: cold compiles in ms, not minutes
                ..Default::default()
            },
            ..Default::default()
        },
        cache_capacity: REQUESTS + HOT_KEYS, // no evictions mid-run
        max_inflight: MAX_INFLIGHT,
        ..Default::default()
    });

    // Key population: hot keys are pre-warmed (index < HOT_KEYS), cold
    // keys are unique FIR lengths no other request shares.
    let rec_for = |i: usize| library::fir(65536 + 1024 * i as u64, 15, DType::F32);
    for i in 0..HOT_KEYS {
        handle.compile(&rec_for(i)).expect("pre-warm hot key");
    }
    let stages = handle
        .compile(&rec_for(0))
        .expect("hot key stays cached")
        .design
        .compile
        .stages;

    // Deterministic arrival schedule: which recurrence each request asks
    // for, fixed before the clock starts (same seed ⇒ same schedule in
    // both runs).
    let mut rng = XorShift64::new(7);
    let mut next_cold = HOT_KEYS;
    let schedule: Vec<usize> = (0..REQUESTS)
        .map(|_| {
            if rng.gen_f64() < HOT_FRACTION {
                rng.gen_range(HOT_KEYS as u64) as usize
            } else {
                next_cold += 1;
                next_cold - 1
            }
        })
        .collect();

    // Open-loop dispatch: request i is *due* at t0 + i/rate regardless
    // of what earlier requests are doing; latency counts from the due
    // time so queueing shows up in the tail.
    let results: Mutex<Vec<(f64, u8)>> = Mutex::new(Vec::with_capacity(REQUESTS));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (i, &key) in schedule.iter().enumerate() {
            let due = Duration::from_secs_f64(i as f64 / RATE_RPS);
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            let handle = handle.clone();
            let rec = rec_for(key);
            let results = &results;
            s.spawn(move || {
                let _ctx = TraceCtx::set(trace::next_trace_id());
                let outcome = match handle.compile(&rec) {
                    Ok(_) => OK,
                    Err(e) if e.downcast_ref::<Overloaded>().is_some() => SHED,
                    Err(_) => ERR,
                };
                let latency_us = (t0.elapsed().saturating_sub(due)).as_secs_f64() * 1e6;
                results.lock().unwrap().push((latency_us, outcome));
            });
        }
    });

    let results = results.into_inner().unwrap();
    assert_eq!(results.len(), REQUESTS, "every arrival must resolve");
    let count = |k: u8| results.iter().filter(|(_, o)| *o == k).count();
    let (ok, shed, err) = (count(OK), count(SHED), count(ERR));
    let mut ok_us: Vec<f64> = results
        .iter()
        .filter(|(_, o)| *o == OK)
        .map(|(us, _)| *us)
        .collect();
    ok_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    LoadReport {
        p50: percentile(&ok_us, 50.0),
        p99: percentile(&ok_us, 99.0),
        p999: percentile(&ok_us, 99.9),
        shed_rate: shed as f64 / REQUESTS as f64,
        ok,
        shed,
        err,
        stats: handle.stats(),
        stage: (stages.place_ms, stages.assign_ms, stages.route_ms),
    }
}

fn main() {
    println!("== serve open-loop load ==");
    println!(
        "{REQUESTS} requests at {RATE_RPS} rps, {:.0}% over {HOT_KEYS} hot keys, max_inflight {MAX_INFLIGHT}",
        HOT_FRACTION * 100.0
    );

    println!("\n-- pass 1/2: uninstrumented (span recording off) --");
    let off = run_load(false);
    println!(
        "ok {} / shed {} / err {} (shed rate {:.1}%)",
        off.ok,
        off.shed,
        off.err,
        off.shed_rate * 100.0
    );
    println!(
        "latency: p50 {:.1} µs, p99 {:.1} µs, p999 {:.1} µs",
        off.p50, off.p99, off.p999
    );

    println!("\n-- pass 2/2: instrumented (span recording on) --");
    let on = run_load(true);
    let trace_events = trace::drain_events().len();
    trace::set_enabled(false);
    println!(
        "ok {} / shed {} / err {} (shed rate {:.1}%), {} trace events",
        on.ok,
        on.shed,
        on.err,
        on.shed_rate * 100.0,
        trace_events
    );
    println!(
        "latency: p50 {:.1} µs, p99 {:.1} µs, p999 {:.1} µs",
        on.p50, on.p99, on.p999
    );

    let overhead_pct = (on.p50 - off.p50) / off.p50 * 100.0;
    println!(
        "\nobs overhead: p50 {:.1} µs → {:.1} µs ({overhead_pct:+.2}%)",
        off.p50, on.p50
    );
    let stats = &off.stats;
    println!(
        "server (uninstrumented pass): {} hits, {} misses, {} deduped, {} shed, {} errors, {} plan hits",
        stats.hits, stats.misses, stats.deduped, stats.shed, stats.errors, stats.plan_hits
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("serve_load".into())),
        ("requests", Json::num_usize(REQUESTS)),
        ("rate_rps", Json::Num(RATE_RPS)),
        ("hot_keys", Json::num_usize(HOT_KEYS)),
        ("hot_fraction", Json::Num(HOT_FRACTION)),
        ("max_inflight", Json::num_usize(MAX_INFLIGHT)),
        ("p50_us", Json::Num(off.p50)),
        ("p99_us", Json::Num(off.p99)),
        ("p999_us", Json::Num(off.p999)),
        ("shed_rate", Json::Num(off.shed_rate)),
        (
            "counts",
            Json::obj(vec![
                ("ok", Json::num_usize(off.ok)),
                ("shed", Json::num_usize(off.shed)),
                ("err", Json::num_usize(off.err)),
            ]),
        ),
        (
            "server",
            Json::obj(vec![
                ("hits", Json::num_u64(stats.hits)),
                ("misses", Json::num_u64(stats.misses)),
                ("deduped", Json::num_u64(stats.deduped)),
                ("shed", Json::num_u64(stats.shed)),
                ("errors", Json::num_u64(stats.errors)),
                ("plan_hits", Json::num_u64(stats.plan_hits)),
            ]),
        ),
        (
            "stage_ms",
            Json::obj(vec![
                ("place", Json::Num(off.stage.0)),
                ("assign", Json::Num(off.stage.1)),
                ("route", Json::Num(off.stage.2)),
            ]),
        ),
        (
            "obs_overhead",
            Json::obj(vec![
                ("p50_off_us", Json::Num(off.p50)),
                ("p50_on_us", Json::Num(on.p50)),
                ("p50_pct", Json::Num(overhead_pct)),
                ("gate_pct", Json::Num(GATE_OVERHEAD_PCT)),
                ("gate_floor_us", Json::Num(GATE_OVERHEAD_FLOOR_US)),
                ("trace_events", Json::num_usize(trace_events)),
            ]),
        ),
        ("gate_p50_us_max", Json::Num(GATE_P50_US)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("BENCH_serve.json");
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_serve.json");
    println!("wrote {}", path.display());

    for (pass, r) in [("uninstrumented", &off), ("instrumented", &on)] {
        if r.ok + r.shed + r.err != REQUESTS {
            eprintln!("FAIL: {pass} outcome counts don't cover every request");
            std::process::exit(1);
        }
        if r.err > 0 {
            eprintln!(
                "FAIL: {} requests errored in the {pass} pass (only ok/shed expected)",
                r.err
            );
            std::process::exit(1);
        }
    }
    if !(off.p50 < GATE_P50_US) {
        eprintln!(
            "FAIL: p50 {:.1} µs exceeds the {GATE_P50_US:.0} µs hit-latency gate",
            off.p50
        );
        std::process::exit(1);
    }
    let allowed = off.p50 * (1.0 + GATE_OVERHEAD_PCT / 100.0) + GATE_OVERHEAD_FLOOR_US;
    if !(on.p50 <= allowed) {
        eprintln!(
            "FAIL: instrumented p50 {:.1} µs exceeds {:.1} µs \
             (uninstrumented {:.1} µs + {GATE_OVERHEAD_PCT}% + {GATE_OVERHEAD_FLOOR_US} µs floor)",
            on.p50, allowed, off.p50
        );
        std::process::exit(1);
    }
    if trace_events == 0 {
        eprintln!("FAIL: instrumented pass recorded no trace events");
        std::process::exit(1);
    }
    println!(
        "\nbench_serve_load OK (p50 under the hit-latency gate, obs overhead {overhead_pct:+.2}% within {GATE_OVERHEAD_PCT}%)"
    );
}
