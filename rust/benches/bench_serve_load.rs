//! Bench: open-loop serve load — tail latency and shed rate under a
//! production-shaped arrival process.
//!
//! Unlike `bench_serve` (closed-loop microbenchmarks of one key), this
//! drives the full admission → cache → single-flight → cold-compile
//! stack the way a fleet does: requests arrive on a fixed open-loop
//! schedule (arrivals don't wait for completions, so queueing delay is
//! *measured*, not hidden), over a mixed key population — `HOT_FRACTION`
//! of requests draw from `HOT_KEYS` pre-warmed designs, the rest are
//! unique cold keys that must compile under a bounded `max_inflight`.
//!
//! Reports p50/p99/p999 request latency (measured from scheduled
//! arrival, the open-loop convention) plus the shed rate, and writes
//! them to `BENCH_serve.json` at the repo root (the committed seed
//! schema is overwritten by `make serve-load-smoke` in CI).
//!
//! Run with `cargo bench --bench bench_serve_load`.

use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::library;
use widesa::serve::{Overloaded, ServeConfig, ServeHandle};
use widesa::util::json::Json;
use widesa::util::rng::XorShift64;
use widesa::{DType, WideSaConfig};

const REQUESTS: usize = 400;
const RATE_RPS: f64 = 400.0;
const HOT_KEYS: usize = 4;
/// Fraction of arrivals that hit the hot key set (the production shape:
/// most traffic re-requests a few Table II-class kernels).
const HOT_FRACTION: f64 = 0.9;
const MAX_INFLIGHT: usize = 2;
/// p50 must stay a hit-latency number, not a compile-latency number: the
/// hot set dominates arrivals, so the median request is a cache probe.
const GATE_P50_US: f64 = 50_000.0;

/// Request outcome classes recorded per arrival.
const OK: u8 = 0;
const SHED: u8 = 1;
const ERR: u8 = 2;

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted_us.len() - 1) as f64).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn main() {
    let handle = ServeHandle::new(ServeConfig {
        base: WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(32), // small budget: cold compiles in ms, not minutes
                ..Default::default()
            },
            ..Default::default()
        },
        cache_capacity: REQUESTS + HOT_KEYS, // no evictions mid-run
        max_inflight: MAX_INFLIGHT,
        ..Default::default()
    });

    // Key population: hot keys are pre-warmed (index < HOT_KEYS), cold
    // keys are unique FIR lengths no other request shares.
    let rec_for = |i: usize| library::fir(65536 + 1024 * i as u64, 15, DType::F32);
    println!("== serve open-loop load ==");
    println!(
        "{REQUESTS} requests at {RATE_RPS} rps, {:.0}% over {HOT_KEYS} hot keys, max_inflight {MAX_INFLIGHT}",
        HOT_FRACTION * 100.0
    );
    for i in 0..HOT_KEYS {
        handle.compile(&rec_for(i)).expect("pre-warm hot key");
    }
    let stage_ms = handle
        .compile(&rec_for(0))
        .expect("hot key stays cached")
        .design
        .compile
        .stages;

    // Deterministic arrival schedule: which recurrence each request asks
    // for, fixed before the clock starts.
    let mut rng = XorShift64::new(7);
    let mut next_cold = HOT_KEYS;
    let schedule: Vec<usize> = (0..REQUESTS)
        .map(|_| {
            if rng.gen_f64() < HOT_FRACTION {
                rng.gen_range(HOT_KEYS as u64) as usize
            } else {
                next_cold += 1;
                next_cold - 1
            }
        })
        .collect();

    // Open-loop dispatch: request i is *due* at t0 + i/rate regardless
    // of what earlier requests are doing; latency counts from the due
    // time so queueing shows up in the tail.
    let results: Mutex<Vec<(f64, u8)>> = Mutex::new(Vec::with_capacity(REQUESTS));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (i, &key) in schedule.iter().enumerate() {
            let due = Duration::from_secs_f64(i as f64 / RATE_RPS);
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            let handle = handle.clone();
            let rec = rec_for(key);
            let results = &results;
            s.spawn(move || {
                let outcome = match handle.compile(&rec) {
                    Ok(_) => OK,
                    Err(e) if e.downcast_ref::<Overloaded>().is_some() => SHED,
                    Err(_) => ERR,
                };
                let latency_us = (t0.elapsed().saturating_sub(due)).as_secs_f64() * 1e6;
                results.lock().unwrap().push((latency_us, outcome));
            });
        }
    });

    let results = results.into_inner().unwrap();
    assert_eq!(results.len(), REQUESTS, "every arrival must resolve");
    let count = |k: u8| results.iter().filter(|(_, o)| *o == k).count();
    let (ok, shed, err) = (count(OK), count(SHED), count(ERR));
    let mut ok_us: Vec<f64> = results
        .iter()
        .filter(|(_, o)| *o == OK)
        .map(|(us, _)| *us)
        .collect();
    ok_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99, p999) = (
        percentile(&ok_us, 50.0),
        percentile(&ok_us, 99.0),
        percentile(&ok_us, 99.9),
    );
    let shed_rate = shed as f64 / REQUESTS as f64;
    let stats = handle.stats();

    println!(
        "ok {ok} / shed {shed} / err {err} (shed rate {:.1}%)",
        shed_rate * 100.0
    );
    println!("latency: p50 {p50:.1} µs, p99 {p99:.1} µs, p999 {p999:.1} µs");
    println!(
        "server: {} hits, {} misses, {} deduped, {} shed, {} errors, {} plan hits",
        stats.hits, stats.misses, stats.deduped, stats.shed, stats.errors, stats.plan_hits
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("serve_load".into())),
        ("requests", Json::num_usize(REQUESTS)),
        ("rate_rps", Json::Num(RATE_RPS)),
        ("hot_keys", Json::num_usize(HOT_KEYS)),
        ("hot_fraction", Json::Num(HOT_FRACTION)),
        ("max_inflight", Json::num_usize(MAX_INFLIGHT)),
        ("p50_us", Json::Num(p50)),
        ("p99_us", Json::Num(p99)),
        ("p999_us", Json::Num(p999)),
        ("shed_rate", Json::Num(shed_rate)),
        (
            "counts",
            Json::obj(vec![
                ("ok", Json::num_usize(ok)),
                ("shed", Json::num_usize(shed)),
                ("err", Json::num_usize(err)),
            ]),
        ),
        (
            "server",
            Json::obj(vec![
                ("hits", Json::num_u64(stats.hits)),
                ("misses", Json::num_u64(stats.misses)),
                ("deduped", Json::num_u64(stats.deduped)),
                ("shed", Json::num_u64(stats.shed)),
                ("errors", Json::num_u64(stats.errors)),
                ("plan_hits", Json::num_u64(stats.plan_hits)),
            ]),
        ),
        (
            "stage_ms",
            Json::obj(vec![
                ("place", Json::Num(stage_ms.place_ms)),
                ("assign", Json::Num(stage_ms.assign_ms)),
                ("route", Json::Num(stage_ms.route_ms)),
            ]),
        ),
        ("gate_p50_us_max", Json::Num(GATE_P50_US)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("BENCH_serve.json");
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_serve.json");
    println!("wrote {}", path.display());

    if ok + shed + err != REQUESTS {
        eprintln!("FAIL: outcome counts don't cover every request");
        std::process::exit(1);
    }
    if err > 0 {
        eprintln!("FAIL: {err} requests errored (only ok/shed are expected under load)");
        std::process::exit(1);
    }
    if !(p50 < GATE_P50_US) {
        eprintln!("FAIL: p50 {p50:.1} µs exceeds the {GATE_P50_US:.0} µs hit-latency gate");
        std::process::exit(1);
    }
    println!("\nbench_serve_load OK (p50 under the hit-latency gate, no errors)");
}
