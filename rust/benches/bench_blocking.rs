//! Bench: blocked replay must beat the naive driver and the DRAM model
//! must match reality.
//!
//! Three checks on the host-level blocking subsystem
//! (`coordinator::blocking` + the planned driver in `coordinator::exec`),
//! all on the `NullArray` host-path backend at N = 2048 so kernel math
//! never pollutes the host-traffic measurement:
//!
//! 1. **Speedup gate** — the planned, double-buffered replay must finish
//!    in ≤ ½ the naive per-tile driver's wall time (≥2×), or this binary
//!    exits non-zero. The win is pure traffic: panel reuse plus the
//!    prefetch thread hiding packing behind the backend calls.
//! 2. **Model gate** — the replay's measured host DRAM bytes must sit
//!    within 10 % of `plan.predicted_dram_bytes` (the same
//!    `CostModel::blocked_mm_dram_bytes` the DSE prices with; by
//!    construction the two agree exactly).
//! 3. **Oracle check** — on the real stub runtime at a ragged shape, the
//!    blocked replay's output bits must equal the serial naive replay's.
//!
//! Also takes a functional GF/s point at N = 1024 through the real stub
//! runtime and writes everything to `BENCH_blocking.json` at the repo
//! root (`widesa trend` folds it into the per-commit trajectory).
//!
//! Run with `cargo bench --bench bench_blocking` (or `make blocking-smoke`).

use std::path::Path;
use widesa::coordinator::exec::{plan_for, run_mm, run_mm_naive, NullArray};
use widesa::runtime::client::Runtime;
use widesa::util::bench::bench;
use widesa::util::json::Json;
use widesa::util::rng::XorShift64;

const N: usize = 2048;
const GATE_SPEEDUP: f64 = 2.0;
const GATE_DRAM_ERR_PCT: f64 = 10.0;

fn random_mm(seed: u64, n: usize, m: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = XorShift64::new(seed);
    let mut a = vec![0f32; n * k];
    let mut b = vec![0f32; k * m];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    (a, b)
}

fn main() {
    let plan = plan_for(N, N, N).expect("2048^3 must be plannable");
    println!("== blocking: planned vs naive MM replay at {N}^3 (NullArray host path) ==");
    println!("{}", plan.summary());
    let (a, b) = random_mm(0xB10C, N, N, N);

    let naive = bench("blocking/naive replay", 3, || {
        std::hint::black_box(run_mm_naive(&mut NullArray, &a, &b, N, N, N).expect("naive"));
    });
    let mut last_stats = None;
    let blocked = bench("blocking/planned replay", 3, || {
        let (_, stats) = run_mm(&mut NullArray, &a, &b, N, N, N).expect("blocked");
        last_stats = Some(stats);
    });
    let stats = last_stats.expect("blocked replay ran");
    let speedup = naive.median_s / blocked.median_s.max(1e-9);
    let predicted = plan.predicted_dram_bytes;
    let measured = stats.dram_bytes;
    let err_pct = (measured as f64 - predicted as f64).abs() / (predicted as f64).max(1.0) * 100.0;
    println!(
        "blocked {:.1} ms vs naive {:.1} ms → {speedup:.2}× | DRAM predicted {:.1} MB, \
         measured {:.1} MB ({err_pct:.2}% off) | pack {:.1} ms, {:.1} ms hidden",
        blocked.median_s * 1e3,
        naive.median_s * 1e3,
        predicted as f64 / 1e6,
        measured as f64 / 1e6,
        stats.pack_ms,
        stats.overlap_hidden_ms,
    );

    // Oracle check: real stub math at a ragged shape, bit-for-bit.
    let (n2, m2, k2) = (300usize, 260usize, 200usize);
    let (a2, b2) = random_mm(0x0AC1E, n2, m2, k2);
    let mut rt = Runtime::new().expect("runtime");
    let (c_blocked, _) = run_mm(&mut rt, &a2, &b2, n2, m2, k2).expect("blocked stub");
    let (c_serial, _) = run_mm_naive(&mut rt, &a2, &b2, n2, m2, k2).expect("serial stub");
    let oracle_ok = c_blocked.len() == c_serial.len()
        && c_blocked
            .iter()
            .zip(&c_serial)
            .all(|(x, y)| x.to_bits() == y.to_bits());
    println!("oracle {n2}x{m2}x{k2} on stub: {}", if oracle_ok { "bit-identical" } else { "DIVERGED" });

    // Functional large-N GF/s point through the real stub runtime.
    let large_n = 1024usize;
    let (a3, b3) = random_mm(0x6F10, large_n, large_n, large_n);
    let t0 = std::time::Instant::now();
    let _ = run_mm(&mut rt, &a3, &b3, large_n, large_n, large_n).expect("stub large-N");
    let large_s = t0.elapsed().as_secs_f64();
    let large_gflops = 2.0 * (large_n as f64).powi(3) / large_s / 1e9;
    println!("functional {large_n}^3 on stub: {large_s:.2} s → {large_gflops:.2} GFLOP/s");

    let out = Json::obj(vec![
        ("bench", Json::Str("blocking".into())),
        ("n", Json::num_u64(N as u64)),
        ("naive_ms", Json::Num(naive.median_s * 1e3)),
        ("blocked_ms", Json::Num(blocked.median_s * 1e3)),
        ("speedup", Json::Num(speedup)),
        ("predicted_dram_bytes", Json::num_u64(predicted)),
        ("measured_dram_bytes", Json::num_u64(measured)),
        ("dram_model_err_pct", Json::Num(err_pct)),
        ("pack_ms", Json::Num(stats.pack_ms)),
        ("overlap_hidden_ms", Json::Num(stats.overlap_hidden_ms)),
        ("large_n", Json::num_u64(large_n as u64)),
        ("large_n_gflops", Json::Num(large_gflops)),
        ("gate_speedup_min", Json::Num(GATE_SPEEDUP)),
        ("gate_dram_err_pct_max", Json::Num(GATE_DRAM_ERR_PCT)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("BENCH_blocking.json");
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_blocking.json");
    println!("wrote {}", path.display());

    let mut failed = false;
    if speedup < GATE_SPEEDUP {
        eprintln!("FAIL: blocked replay only {speedup:.2}× the naive driver (gate {GATE_SPEEDUP}×)");
        failed = true;
    }
    if err_pct > GATE_DRAM_ERR_PCT {
        eprintln!(
            "FAIL: DRAM model off by {err_pct:.2}% (gate {GATE_DRAM_ERR_PCT}%): \
             predicted {predicted} B, measured {measured} B"
        );
        failed = true;
    }
    if !oracle_ok {
        eprintln!("FAIL: blocked replay diverged from the serial oracle at {n2}x{m2}x{k2}");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("\nbench_blocking OK (≥{GATE_SPEEDUP}× naive, DRAM model within {GATE_DRAM_ERR_PCT}%, oracle bit-identical)");
}
