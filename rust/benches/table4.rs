//! Bench: regenerate Table IV (E2) — PL-only (AutoSA) vs WideSA energy
//! efficiency, timing the per-dtype evaluation.

use widesa::baselines::autosa_pl;
use widesa::eval::table4;
use widesa::recurrence::dtype::DType;
use widesa::util::bench::bench;

fn main() {
    println!("== bench table4: per-dtype evaluation cost ==");
    for dtype in [DType::F32, DType::I8, DType::I16, DType::I32] {
        bench(&format!("autosa-pl-model/{dtype}"), 50, || {
            std::hint::black_box(autosa_pl::design(dtype).tops);
        });
    }
    bench("table4/full", 3, || {
        let (rows, _) = table4::run();
        std::hint::black_box(rows.len());
    });

    println!("\n== regenerated Table IV ==");
    let (_, table) = table4::run();
    println!("{table}");
}
