//! Bench: the serve layer's two performance claims.
//!
//! 1. **Cache amortization** — a cache-hit request must complete ≥ 100×
//!    faster than a cold compile of the same key (it is a sharded-map
//!    lookup plus an `Arc` clone, vs DSE + P&R + simulation + codegen).
//!    This binary *enforces* the ratio: it exits non-zero below 100×.
//! 2. **DSE sharding** — candidate scoring sharded across threads
//!    against the serial `explore_all` reference (identical ranking,
//!    lower wall time on multi-core).
//!
//! Run with `cargo bench --bench bench_serve`.

use std::time::Instant;
use widesa::mapping::dse::{explore_all, explore_all_parallel, DseConstraints};
use widesa::recurrence::library;
use widesa::serve::{CacheOutcome, ServeConfig, ServeHandle};
use widesa::util::bench::bench;
use widesa::{DType, WideSaConfig};

fn main() {
    let threads = std::thread::available_parallelism().map_or(4, |n| n.get());
    let handle = ServeHandle::new(ServeConfig::default());
    let rec = library::mm(8192, 8192, 8192, DType::F32);

    println!("== serve: cache hit vs cold compile ==");
    let t0 = Instant::now();
    let cold = handle.compile(&rec).expect("cold compile");
    let cold_s = t0.elapsed().as_secs_f64();
    assert_eq!(cold.outcome, CacheOutcome::Miss);
    println!("cold compile (miss): {:.3} ms", cold_s * 1e3);

    let hit = bench("serve/cache-hit", 2000, || {
        let r = handle.compile(&rec).expect("hit");
        assert_eq!(r.outcome, CacheOutcome::Hit);
        std::hint::black_box(r.design.estimate.perf.tops);
    });
    let speedup = cold_s / hit.median_s.max(1e-12);
    println!("cache-hit speedup over cold compile: {speedup:.0}×");

    println!("\n== serve: sharded DSE scoring ({threads} cores) ==");
    let board = WideSaConfig::default().board;
    let cons = DseConstraints::default();
    let serial = bench("dse/explore-all serial", 30, || {
        std::hint::black_box(explore_all(&rec, &board, &cons).len());
    });
    let parallel = bench(&format!("dse/explore-all ×{threads}"), 30, || {
        std::hint::black_box(explore_all_parallel(&rec, &board, &cons, threads).len());
    });
    println!(
        "parallel DSE speedup: {:.2}× (serial {:.3} ms → parallel {:.3} ms)",
        serial.median_s / parallel.median_s.max(1e-12),
        serial.median_s * 1e3,
        parallel.median_s * 1e3,
    );

    if speedup < 100.0 {
        eprintln!("FAIL: cache-hit speedup {speedup:.0}× is below the required 100×");
        std::process::exit(1);
    }
    println!("\nbench_serve OK (cache-hit ≥ 100× cold compile)");
}
