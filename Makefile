# WideSA build entry points.
#
# The rust workspace is self-contained (`make build` / `make test` need no
# python). `make artifacts` AOT-lowers the L2 variants to HLO text for the
# optional PJRT runtime backend; it requires a JAX install (see
# python/README.md) and is a no-op for the default stub backend.
# `make serve-smoke` pipes three JSON-lines requests through the compile
# service and asserts three responses come back.

ARTIFACTS := artifacts
SERVE_SMOKE_OUT := target/serve-smoke.out

.PHONY: build test bench doc artifacts serve-smoke serve-load-smoke mutation-smoke rank-smoke pnr-smoke workloads-smoke clean

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

serve-smoke: build
	printf '%s\n%s\n%s\n' \
	  '{"id":1,"bench":"fir","dims":[65536,15],"max_aies":32}' \
	  '{"id":2,"bench":"fir","dims":[65536,15],"max_aies":32}' \
	  '{"id":3,"bench":"mm","dims":[1024,1024,1024],"max_aies":64}' \
	  | ./target/release/widesa serve --stdin --workers 2 > $(SERVE_SMOKE_OUT)
	@test "$$(grep -c '"ok":true' $(SERVE_SMOKE_OUT))" -eq 3 \
	  || { echo "serve-smoke FAILED:"; cat $(SERVE_SMOKE_OUT); exit 1; }
	@grep -Eq '"(cached|deduped)":true' $(SERVE_SMOKE_OUT) \
	  || { echo "serve-smoke FAILED: duplicate request was neither cached nor deduplicated"; cat $(SERVE_SMOKE_OUT); exit 1; }
	@echo "serve-smoke OK (3 responses, duplicate amortized)"

# Gate the production-serve layer under open-loop load: replay a
# deterministic 400 req/s arrival schedule (90 % hot keys, cold-compile
# queue capped at 2) against a pre-warmed service. Every request must
# resolve as ok or a typed shed (no errors), hot p50 must stay under the
# latency gate, and BENCH_serve.json at the repo root is refreshed with
# p50/p99/p999 latency and the shed rate.
serve-load-smoke:
	cargo bench --bench bench_serve_load

# Mutation-style suite smoke: prove the tests would notice. Positive
# controls first (each guard passes unmutated), then each WIDESA_MUTATE
# seam must make its guard FAIL — a suite that still passes under a
# halved cost-model peak or a disabled admission quota is not testing
# what it claims to.
mutation-smoke:
	cargo test -q --lib mm_f32_lands_near_paper
	cargo test -q --lib quota_admission_is_per_tenant
	! WIDESA_MUTATE=cost-peak cargo test -q --lib mm_f32_lands_near_paper
	! WIDESA_MUTATE=quota-grant cargo test -q --lib quota_admission_is_per_tenant
	@echo "mutation-smoke OK (both seams detected)"

# Gate the exact-port ranking: scoring a candidate with exact merged
# port counts must cost ≤ 2× the legacy analytic score (bench_rank exits
# non-zero above the bound).
rank-smoke:
	cargo bench --bench bench_rank

# Gate the dense-index P&R hot path: the flat-array annealer must stay
# bit-identical to the retained HashMap baseline (equivalence corpus)
# and deliver ≥2× its iteration throughput on the E5 400-AIE workload
# (bench_compile exits non-zero below the gate). Also refreshes
# BENCH_compile.json at the repo root — the compile-latency trajectory.
pnr-smoke:
	cargo test -q --features legacy-hash-pnr --test pnr_equivalence
	cargo bench --bench bench_compile --features legacy-hash-pnr

# Gate the expanded workload catalog: every library workload (MM, Conv2D,
# FIR, 2D-FFT, depthwise conv, triangular solve, stencil chain) must
# compile to a legal design, stub-execute bit-correct against its
# coordinator::verify oracle, and keep sim/analytic agreement ≤15 % —
# then print the coverage table.
workloads-smoke: build
	cargo test -q --test integration_workloads
	./target/release/widesa workloads

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts: $(ARTIFACTS)/manifest.json

$(ARTIFACTS)/manifest.json: python/compile/model.py python/compile/aot.py python/compile/kernels/*.py
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS)

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
