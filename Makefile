# WideSA build entry points.
#
# The rust workspace is self-contained (`make build` / `make test` need no
# python). `make artifacts` AOT-lowers the L2 variants to HLO text for the
# optional PJRT runtime backend; it requires a JAX install (see
# python/README.md) and is a no-op for the default stub backend.
# `make serve-smoke` pipes three JSON-lines requests through the compile
# service and asserts three responses come back.

ARTIFACTS := artifacts
SERVE_SMOKE_OUT := target/serve-smoke.out
OBS_SMOKE_DIR := target/obs-smoke

.PHONY: build test bench doc artifacts serve-smoke serve-load-smoke obs-smoke mutation-smoke rank-smoke pnr-smoke workloads-smoke ca-smoke energy-smoke blocking-smoke clean

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

serve-smoke: build
	printf '%s\n%s\n%s\n' \
	  '{"id":1,"bench":"fir","dims":[65536,15],"max_aies":32}' \
	  '{"id":2,"bench":"fir","dims":[65536,15],"max_aies":32}' \
	  '{"id":3,"bench":"mm","dims":[1024,1024,1024],"max_aies":64}' \
	  | ./target/release/widesa serve --stdin --workers 2 > $(SERVE_SMOKE_OUT)
	@test "$$(grep -c '"ok":true' $(SERVE_SMOKE_OUT))" -eq 3 \
	  || { echo "serve-smoke FAILED:"; cat $(SERVE_SMOKE_OUT); exit 1; }
	@grep -Eq '"(cached|deduped)":true' $(SERVE_SMOKE_OUT) \
	  || { echo "serve-smoke FAILED: duplicate request was neither cached nor deduplicated"; cat $(SERVE_SMOKE_OUT); exit 1; }
	@echo "serve-smoke OK (3 responses, duplicate amortized)"

# Gate the production-serve layer under open-loop load: replay a
# deterministic 400 req/s arrival schedule (90 % hot keys, cold-compile
# queue capped at 2) against a pre-warmed service — twice, span recording
# off then on. Every request must resolve as ok or a typed shed (no
# errors), hot p50 must stay under the latency gate, instrumented p50
# must stay within 5 % of uninstrumented (+ a 250 µs noise floor), and
# BENCH_serve.json at the repo root is refreshed with p50/p99/p999
# latency, the shed rate, and the obs_overhead comparison.
serve-load-smoke:
	cargo bench --bench bench_serve_load

# Gate the observability exports end-to-end: serve 20 requests (plus an
# in-band stats command) through the stdin front-end with --trace-out
# and --metrics-out, then validate both files with `widesa obs-check`
# (well-formed Chrome trace, span nesting, trace IDs, root coverage,
# both metric registries present), then run the overhead gate.
obs-smoke: build
	mkdir -p $(OBS_SMOKE_DIR)
	for i in $$(seq 1 20); do \
	  echo "{\"id\":$$i,\"bench\":\"fir\",\"dims\":[$$((65536 + (i % 5) * 1024)),15],\"max_aies\":32}"; \
	done > $(OBS_SMOKE_DIR)/requests.jsonl
	echo '{"cmd":"stats","id":99}' >> $(OBS_SMOKE_DIR)/requests.jsonl
	./target/release/widesa serve --stdin --workers 2 \
	  --trace-out $(OBS_SMOKE_DIR)/trace.json \
	  --metrics-out $(OBS_SMOKE_DIR)/metrics.json \
	  < $(OBS_SMOKE_DIR)/requests.jsonl > $(OBS_SMOKE_DIR)/responses.jsonl
	@test "$$(grep -c '"ok":true' $(OBS_SMOKE_DIR)/responses.jsonl)" -eq 21 \
	  || { echo "obs-smoke FAILED: expected 21 ok responses:"; cat $(OBS_SMOKE_DIR)/responses.jsonl; exit 1; }
	@grep -q '"serve.request_us"' $(OBS_SMOKE_DIR)/metrics.json \
	  || { echo "obs-smoke FAILED: request histogram missing from metrics export"; exit 1; }
	./target/release/widesa obs-check \
	  --trace $(OBS_SMOKE_DIR)/trace.json --metrics $(OBS_SMOKE_DIR)/metrics.json
	$(MAKE) serve-load-smoke
	@echo "obs-smoke OK (trace + metrics validated, overhead gate passed)"

# Mutation-style suite smoke: prove the tests would notice. Positive
# controls first (each guard passes unmutated), then each WIDESA_MUTATE
# seam must make its guard FAIL — a suite that still passes under a
# halved cost-model peak, a disabled admission quota, an off-by-one
# histogram bucketing, a +7 W static-power drift, a blocking pricer
# that forgets streamed-panel reloads, or a CA pricer that forgets
# partial-sum reduction traffic is not testing what it claims to.
mutation-smoke:
	cargo test -q --lib mm_f32_lands_near_paper
	cargo test -q --lib quota_admission_is_per_tenant
	cargo test -q --lib histogram_bucketing_is_exact
	cargo test -q --lib widesa_power_near_55w
	cargo test -q --lib blocking_planner_prices_true_reuse
	cargo test -q --lib ca_pricer_charges_partial_sum_reduction
	! WIDESA_MUTATE=cost-peak cargo test -q --lib mm_f32_lands_near_paper
	! WIDESA_MUTATE=quota-grant cargo test -q --lib quota_admission_is_per_tenant
	! WIDESA_MUTATE=obs-bucket cargo test -q --lib histogram_bucketing_is_exact
	! WIDESA_MUTATE=power-static cargo test -q --lib widesa_power_near_55w
	! WIDESA_MUTATE=blocking-reuse cargo test -q --lib blocking_planner_prices_true_reuse
	! WIDESA_MUTATE=ca-reduce cargo test -q --lib ca_pricer_charges_partial_sum_reduction
	@echo "mutation-smoke OK (all six seams detected)"

# Gate the exact-port ranking: scoring a candidate with exact merged
# port counts must cost ≤ 2× the legacy analytic score (bench_rank exits
# non-zero above the bound).
rank-smoke:
	cargo bench --bench bench_rank

# Gate the dense-index P&R hot path: the flat-array annealer must stay
# bit-identical to the retained HashMap baseline (equivalence corpus)
# and deliver ≥2× its iteration throughput on the E5 400-AIE workload
# (bench_compile exits non-zero below the gate). Also refreshes
# BENCH_compile.json at the repo root — the compile-latency trajectory.
pnr-smoke:
	cargo test -q --features legacy-hash-pnr --test pnr_equivalence
	cargo bench --bench bench_compile --features legacy-hash-pnr

# Gate the expanded workload catalog: every library workload (MM, Conv2D,
# FIR, 2D-FFT, depthwise conv, triangular solve, stencil chain) must
# compile to a legal design, stub-execute bit-correct against its
# coordinator::verify oracle, and keep sim/analytic agreement ≤15 % —
# then print the coverage table.
workloads-smoke: build
	cargo test -q --test integration_workloads
	./target/release/widesa workloads

# Gate the communication-avoiding mapping arm: the form-selection law
# (CA crowned iff the standard form is PLIO-bound, predictor re-verified
# against the real merge) over the library's CA pairs and testkit-random
# replication-axis shapes, the CA candidate port/ranking properties, the
# Gauss–Seidel skew-fallback case, and the CA/seidel replay drivers —
# then print the standard-vs-CA selection table across channel budgets,
# refreshing BENCH_ca.json at the repo root (docs/CA_VARIANTS.md).
ca-smoke: build
	cargo test -q --test divergence_corpus ca_selected_iff_port_bound_across_the_corpus
	cargo test -q --test proptest_invariants prop_ca_candidates_obey_port_and_ranking_laws
	cargo test -q --test integration_workloads seidel_is_only_mappable_via_the_skew_fallback
	cargo test -q --lib ca_
	cargo test -q --lib seidel
	./target/release/widesa ca

# Gate the energy pathway: the shared power model must keep the Table IV
# calibration (fp32 MM normalised TOPS/W within tolerance), every energy
# row must carry a consistent power estimate and a non-empty Pareto
# frontier, and the Pareto ranking law (non-dominated frontier,
# insertion-order independence, serial ≡ parallel) must hold on the
# Table II corpus — then print the energy table (docs/ENERGY.md).
energy-smoke: build
	cargo test -q --lib eval::energy
	cargo test -q --lib eval::table4
	cargo test -q --test divergence_corpus pareto_law_holds_on_all_table2_recurrences
	cargo test -q --test cache_compat
	./target/release/widesa energy

# Gate the host-level blocked GEMM path: the oracle-equivalence corpus
# (blocked + double-buffered replay bit-identical to the serial naive
# driver over targeted and testkit-random shapes, typed Unplannable
# end-to-end), then bench_blocking — the planned replay must run ≥2×
# the naive driver at 2048³ on the NullArray host path and the measured
# host DRAM bytes must sit within 10 % of the plan's prediction (it
# exits non-zero otherwise). Refreshes BENCH_blocking.json at the repo
# root; see docs/BLOCKING.md.
blocking-smoke:
	cargo test -q --test integration_blocking
	cargo bench --bench bench_blocking

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts: $(ARTIFACTS)/manifest.json

$(ARTIFACTS)/manifest.json: python/compile/model.py python/compile/aot.py python/compile/kernels/*.py
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS)

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
