# WideSA build entry points.
#
# The rust workspace is self-contained (`make build` / `make test` need no
# python). `make artifacts` AOT-lowers the L2 variants to HLO text for the
# optional PJRT runtime backend; it requires a JAX install (see
# python/README.md) and is a no-op for the default stub backend.

ARTIFACTS := artifacts

.PHONY: build test bench doc artifacts clean

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts: $(ARTIFACTS)/manifest.json

$(ARTIFACTS)/manifest.json: python/compile/model.py python/compile/aot.py python/compile/kernels/*.py
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS)

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
