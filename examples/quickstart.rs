//! Quickstart: map one matrix multiplication onto the (simulated) VCK5000
//! with WideSA and print everything the framework decides.
//!
//! Run: `cargo run --release --example quickstart`

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};

fn main() -> anyhow::Result<()> {
    // 1. Describe the computation as a uniform recurrence.
    let rec = library::mm(8192, 8192, 8192, DType::F32);
    println!("recurrence: {} ({} MACs)", rec.name, rec.total_macs());
    for dep in rec.dependences() {
        println!("  dependence: {dep}");
    }

    // 2. Configure the framework (defaults = full VCK5000, 512-bit movers).
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
        ..Default::default()
    });

    // 3. Compile: demarcation → space-time DSE → graph → PLIO assignment
    //    → place & route → simulation → code generation.
    let design = ws.compile(&rec)?;
    println!("\n{}", design.report());

    // 4. Inspect the generated AIE kernel (one program serves all cores).
    println!("generated AIE kernel (first 20 lines):");
    for line in design.code.aie_kernel.lines().take(20) {
        println!("  {line}");
    }
    Ok(())
}
